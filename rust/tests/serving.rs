//! Concurrency coverage for the 0.6.0 multi-tenant layer: programs
//! compiled from one shared `Session` running on many threads must be
//! bitwise identical to serial execution with flat per-program tensor
//! allocations, the plan cache must survive concurrent access, and a
//! `Server` must sustain concurrent `run_into` traffic with zero
//! steady-state tensor allocations per request.
//!
//! The CI chaos leg re-runs this suite with `DEINSUM_FAULT_SEED` set,
//! which arms the env-seeded fault plan on every server built here
//! (strided transient run failures, worker panics, latency — see
//! `deinsum::fault`).  Under that flag the *exactness* asserts (zero
//! errors, flat allocations, warm hit rates) are relaxed — injected
//! faults legitimately consume retry budgets and drop warm programs —
//! but the load-bearing invariants hold unconditionally: every accepted
//! ticket resolves (`completed + errors == submitted`, nothing hangs)
//! and every *successful* reply is bitwise identical to the fault-free
//! serial reference.

use std::sync::Arc;

use deinsum::{ServeRequest, Server, Session, Tensor};

/// True on the CI chaos leg: servers built without an explicit
/// `fault_plan` inherit the `DEINSUM_FAULT_SEED`-seeded plan, so
/// injected faults are expected traffic.
fn faults_active() -> bool {
    std::env::var("DEINSUM_FAULT_SEED").is_ok()
}

/// A mixed workload: MTTKRP all three modes (one with a permuted
/// output), a TTMc-shaped chain, plain and transposed GEMM, and a
/// 2MM chain — eight distinct program keys.
fn mixed_workload() -> Vec<(&'static str, Vec<Vec<usize>>)> {
    let n = 12usize;
    let r = 4usize;
    vec![
        ("ijk,ja,ka->ia", vec![vec![n, n, n], vec![n, r], vec![n, r]]),
        ("ijk,ia,ka->ja", vec![vec![n, n, n], vec![n, r], vec![n, r]]),
        ("ijk,ia,ja->ka", vec![vec![n, n, n], vec![n, r], vec![n, r]]),
        ("ijk,ja,ka->ai", vec![vec![n, n, n], vec![n, r], vec![n, r]]),
        ("ijkl,jb,kc,ld->ibcd", vec![vec![6, 6, 6, 6], vec![6, 3], vec![6, 3], vec![6, 3]]),
        ("ij,jk->ik", vec![vec![16, 12], vec![12, 8]]),
        ("ij,jk->ki", vec![vec![16, 12], vec![12, 8]]),
        ("ij,jk,kl->il", vec![vec![10, 8], vec![8, 12], vec![12, 6]]),
    ]
}

fn inputs_for(shapes: &[Vec<usize>], seed: u64) -> Arc<Vec<Tensor>> {
    Arc::new(
        shapes
            .iter()
            .enumerate()
            .map(|(i, s)| Tensor::random(s, seed + i as u64))
            .collect(),
    )
}

#[test]
fn concurrent_programs_from_one_session_match_serial_bitwise() {
    let session = Arc::new(Session::builder().ranks(4).build().unwrap());
    let work = mixed_workload();
    let inputs: Vec<Arc<Vec<Tensor>>> =
        (0..work.len()).map(|i| inputs_for(&work[i].1, 1000 + 100 * i as u64)).collect();

    // Serial reference: one program per key, run once.
    let serial: Vec<Tensor> = work
        .iter()
        .zip(&inputs)
        .map(|((expr, shapes), ins)| {
            session.compile(expr, shapes).unwrap().run(ins).unwrap().output
        })
        .collect();

    // Concurrent: one thread per key, each compiling its own program
    // from the SAME session (all compiles are now cache hits sharing the
    // serial pass's plans), re-running it with recycled outputs.  Every
    // rerun must be bitwise identical to serial, and per-program tensor
    // allocations must be flat after warmup.
    std::thread::scope(|s| {
        for (((expr, shapes), ins), want) in work.iter().zip(&inputs).zip(&serial) {
            let session = Arc::clone(&session);
            s.spawn(move || {
                let mut prog = session.compile(expr, shapes).unwrap();
                let mut out = Tensor::zeros(&prog.output_dims());
                for _ in 0..2 {
                    prog.run_into(ins, &mut out).unwrap();
                }
                assert!(out.allclose(want, 0.0, 0.0), "{expr}: warmup diverged from serial");
                // RunStats::tensor_allocs deliberately excludes the
                // session-wide engine packing pool, whose high-water
                // mark depends on which programs ran concurrently.
                let warm = prog.stats().tensor_allocs();
                for _ in 0..3 {
                    prog.run_into(ins, &mut out).unwrap();
                    assert!(
                        out.allclose(want, 0.0, 0.0),
                        "{expr}: concurrent rerun diverged from serial"
                    );
                }
                assert_eq!(
                    prog.stats().tensor_allocs(),
                    warm,
                    "{expr}: steady-state rerun allocated tensors under concurrency"
                );
            });
        }
    });
    let cs = session.cache_stats();
    assert_eq!(cs.misses, work.len() as u64, "serial pass planned each key exactly once");
    assert_eq!(cs.hits, work.len() as u64, "every concurrent compile must hit the cache");
}

#[test]
fn plan_cache_survives_concurrent_compile_stress() {
    // Loom-free stress: 8 threads hammer the shared cache with a mix of
    // hits and misses.  Invariants: every compile is counted exactly
    // once (hits + misses == total), capacity is respected, and every
    // returned program is runnable.
    let session = Arc::new(
        Session::builder().ranks(2).plan_cache_capacity(4).build().unwrap(),
    );
    let specs: Vec<(String, Vec<Vec<usize>>)> = (0..6)
        .map(|i| ("ij,jk->ik".to_string(), vec![vec![8 + 2 * i, 6], vec![6, 4]]))
        .collect();
    let threads = 8usize;
    let iters = 12usize;
    std::thread::scope(|s| {
        for t in 0..threads {
            let session = Arc::clone(&session);
            let specs = &specs;
            s.spawn(move || {
                for i in 0..iters {
                    let (expr, shapes) = &specs[(t + i) % specs.len()];
                    let mut prog = session.compile(expr, shapes).unwrap();
                    if i == 0 {
                        // Each thread also executes once: compiled
                        // handles must be immediately usable.
                        let ins: Vec<Tensor> = shapes
                            .iter()
                            .map(|sh| Tensor::random(sh, t as u64))
                            .collect();
                        let rep = prog.run(&ins).unwrap();
                        assert_eq!(rep.output.dims(), prog.output_dims());
                    }
                }
            });
        }
    });
    let cs = session.cache_stats();
    assert_eq!(
        cs.hits + cs.misses,
        (threads * iters) as u64,
        "every compile is exactly one counted hit or miss: {cs:?}"
    );
    // 6 distinct keys in a 4-entry cache: evictions must have happened,
    // and the cache never exceeds its bound.
    assert!(session.cached_plans() <= 4);
    assert!(cs.misses >= 6, "each distinct key planned at least once: {cs:?}");
}

#[test]
fn server_with_8_workers_sustains_concurrent_traffic_with_zero_steady_state_allocs() {
    // The acceptance pin: an 8-worker server serving mixed traffic from
    // two tenants over programs compiled from ONE session returns
    // bitwise-identical outputs vs serial execution, and once every
    // program is warm, requests perform zero tensor allocations
    // (counter-asserted through the server's own accounting).
    let work = mixed_workload();
    let inputs: Vec<Arc<Vec<Tensor>>> =
        (0..work.len()).map(|i| inputs_for(&work[i].1, 5000 + 100 * i as u64)).collect();

    // Serial reference on an independent session (identical settings →
    // identical plans → bitwise-identical outputs).
    let reference: Vec<Tensor> = {
        let s = Session::builder().ranks(4).build().unwrap();
        work.iter()
            .zip(&inputs)
            .map(|((expr, shapes), ins)| {
                s.compile(expr, shapes).unwrap().run(ins).unwrap().output
            })
            .collect()
    };

    let session = Session::builder().ranks(4).build().unwrap();
    let server = Server::builder(session).workers(8).queue_capacity(32).build();
    let submit_round = |tenant: &str| -> Vec<deinsum::Ticket> {
        work.iter()
            .zip(&inputs)
            .map(|((expr, shapes), ins)| {
                server
                    .submit(ServeRequest {
                        tenant: tenant.into(),
                        expr: (*expr).into(),
                        shapes: shapes.clone(),
                        inputs: Arc::clone(ins),
                        dest: Tensor::zeros(
                            &Server::output_dims(expr, shapes).unwrap(),
                        ),
                    })
                    .unwrap()
            })
            .collect()
    };

    // Under the chaos leg, injected faults may legitimately exhaust a
    // request's retry budget: accept only the typed retryable classes.
    let chaos = faults_active();
    let wait_one = |ticket: deinsum::Ticket| -> Option<deinsum::ServeReply> {
        match ticket.wait() {
            Ok(reply) => Some(reply),
            Err(e) if chaos && e.is_retryable() => None,
            Err(e) => panic!("request failed outside injected-fault classes: {e}"),
        }
    };

    // Warmup: two rounds so every key's owning worker holds a warm
    // program and every recycled path (including permuted gathers) has
    // its buffers.
    for _ in 0..2 {
        for ticket in submit_round("warmup") {
            wait_one(ticket);
        }
    }
    let warm = server.stats();
    if !chaos {
        assert_eq!(warm.errors, 0, "warmup must succeed: {warm:?}");
        assert_eq!(warm.completed, 2 * work.len() as u64);
        assert_eq!(
            warm.program_misses,
            work.len() as u64,
            "each key instantiates exactly one program (key-affinity routing): {warm:?}"
        );
    }

    // Steady state: three interleaved rounds from two tenants, all in
    // flight together.
    let mut all_tickets = Vec::new();
    for _ in 0..3 {
        for tenant in ["tenant-a", "tenant-b"] {
            all_tickets.push((tenant, submit_round(tenant)));
        }
    }
    for (_, tickets) in all_tickets {
        for (ticket, want) in tickets.into_iter().zip(&reference) {
            if let Some(reply) = wait_one(ticket) {
                assert!(
                    reply.output.allclose(want, 0.0, 0.0),
                    "served output diverged from serial reference"
                );
            }
        }
    }

    let after = server.stats();
    // Unconditional: every accepted ticket resolved, nothing hangs.
    assert_eq!(after.submitted, 8 * work.len() as u64);
    assert_eq!(after.completed + after.errors, after.submitted, "zero lost tickets");
    assert_eq!(after.in_flight, 0);
    assert!(after.p50_latency_s <= after.p99_latency_s);
    if !chaos {
        assert_eq!(after.errors, 0);
        assert_eq!(after.completed, warm.completed + 6 * work.len() as u64);
        assert_eq!(
            after.tensor_allocs, warm.tensor_allocs,
            "steady-state serving must perform zero tensor allocations per request \
             ({warm:?} -> {after:?})"
        );
        assert!(after.tensor_reuses > warm.tensor_reuses, "requests must recycle buffers");
        assert_eq!(after.program_misses, warm.program_misses, "no program re-instantiation");
        assert!(after.throughput_rps > 0.0);
        assert!(after.hit_rate() > 0.8, "steady state must be warm-program hits: {after:?}");
    }

    // Per-tenant accounting: both tenants saw all three rounds.
    for tenant in ["tenant-a", "tenant-b"] {
        let ts = server.tenant_stats(tenant).unwrap();
        assert_eq!(
            ts.completed + ts.errors,
            3 * work.len() as u64,
            "{tenant}: every request resolved: {ts:?}"
        );
        assert_eq!(ts.in_flight, 0);
        if !chaos {
            assert_eq!(ts.completed, 3 * work.len() as u64, "{tenant}: {ts:?}");
            assert_eq!(ts.errors, 0);
        }
    }
    assert_eq!(server.tenants(), vec!["tenant-a", "tenant-b", "warmup"]);
}

#[test]
fn bounded_queue_applies_backpressure_without_losing_requests() {
    // One worker, tiny queue: submitters block instead of erroring or
    // dropping; every request completes exactly once.
    let session = Session::builder().ranks(2).build().unwrap();
    let server =
        Arc::new(Server::builder(session).workers(1).queue_capacity(2).build());
    let shapes = vec![vec![8, 6], vec![6, 4]];
    let ins = inputs_for(&shapes, 77);
    let chaos = faults_active();
    std::thread::scope(|s| {
        for t in 0..4 {
            let server = Arc::clone(&server);
            let shapes = shapes.clone();
            let ins = Arc::clone(&ins);
            s.spawn(move || {
                for _ in 0..4 {
                    let ticket = server
                        .submit(ServeRequest {
                            tenant: format!("client-{t}"),
                            expr: "ij,jk->ik".into(),
                            shapes: shapes.clone(),
                            inputs: Arc::clone(&ins),
                            dest: Tensor::zeros(&[8, 4]),
                        })
                        .unwrap();
                    match ticket.wait() {
                        Ok(_) => {}
                        Err(e) if chaos && e.is_retryable() => {}
                        Err(e) => panic!("request failed outside injected faults: {e}"),
                    }
                }
            });
        }
    });
    let st = server.stats();
    assert_eq!(st.submitted, 16);
    assert_eq!(st.completed + st.errors, 16, "zero lost tickets: {st:?}");
    if !chaos {
        assert_eq!((st.completed, st.errors), (16, 0));
    }
    assert_eq!(st.queue_depth, 0);
    assert_eq!(st.in_flight, 0);
    assert_eq!(server.tenants().len(), 4);
}

#[test]
fn coalesced_batch_is_bitwise_identical_to_serial_runs_with_flat_allocs() {
    // The batched-serving acceptance pin: a single worker is pinned on a
    // deliberately heavy "blocker" request while a burst of same-key
    // requests (distinct inputs each) queues behind it, so the next
    // drain coalesces the burst and serves it through the fused
    // `run_batch_into` path.  Every reply must be bitwise identical to a
    // serial `run_into` reference, the fused path must actually engage
    // (`ServeStats::batched`), and once warm the batched path must
    // perform zero tensor allocations per round.
    let blocker_expr = "ij,jk,kl->il";
    let blocker_shapes = vec![vec![192, 192], vec![192, 192], vec![192, 192]];
    let expr = "ijk,ja,ka->ia";
    let shapes = vec![vec![12, 10, 8], vec![10, 4], vec![8, 4]];
    let burst = 8usize;
    let rounds = 6usize;

    // Per-member serial references (distinct inputs per member) from an
    // independent, identically-configured session.
    let member_inputs: Vec<Arc<Vec<Tensor>>> =
        (0..burst).map(|k| inputs_for(&shapes, 9000 + 10 * k as u64)).collect();
    let blocker_inputs = inputs_for(&blocker_shapes, 8700);
    let reference: Vec<Tensor> = {
        let s = Session::builder().ranks(4).build().unwrap();
        let mut prog = s.compile(expr, &shapes).unwrap();
        member_inputs
            .iter()
            .map(|ins| {
                let mut out = Tensor::zeros(&prog.output_dims());
                prog.run_into(ins, &mut out).unwrap();
                out
            })
            .collect()
    };

    let session = Session::builder().ranks(4).build().unwrap();
    let server = Server::builder(session).workers(1).queue_capacity(32).build();
    let chaos = faults_active();
    let wait_one = |ticket: deinsum::Ticket| -> Option<deinsum::ServeReply> {
        match ticket.wait() {
            Ok(reply) => Some(reply),
            Err(e) if chaos && e.is_retryable() => None,
            Err(e) => panic!("request failed outside injected-fault classes: {e}"),
        }
    };

    let mut warm_allocs = None;
    for round in 0..rounds {
        // The blocker occupies the single worker; the burst submitted
        // behind it lands in the queue together and coalesces.
        let blocker = server
            .submit(ServeRequest {
                tenant: "batch".into(),
                expr: blocker_expr.into(),
                shapes: blocker_shapes.clone(),
                inputs: Arc::clone(&blocker_inputs),
                dest: Tensor::zeros(
                    &Server::output_dims(blocker_expr, &blocker_shapes).unwrap(),
                ),
            })
            .unwrap();
        let tickets: Vec<deinsum::Ticket> = member_inputs
            .iter()
            .map(|ins| {
                server
                    .submit(ServeRequest {
                        tenant: "batch".into(),
                        expr: expr.into(),
                        shapes: shapes.clone(),
                        inputs: Arc::clone(ins),
                        dest: Tensor::zeros(&Server::output_dims(expr, &shapes).unwrap()),
                    })
                    .unwrap()
            })
            .collect();
        wait_one(blocker);
        for (ticket, want) in tickets.into_iter().zip(&reference) {
            if let Some(reply) = wait_one(ticket) {
                assert!(
                    reply.output.allclose(want, 0.0, 0.0),
                    "round {round}: batched reply diverged from serial run_into reference"
                );
            }
        }
        // Allocation fixed point: batch-member buffer sets (`#b1`..) are
        // sized by the largest batch seen, so once two consecutive
        // rounds allocate nothing the steady state is reached and every
        // later round must stay flat.
        let allocs = server.stats().tensor_allocs;
        if !chaos && round >= 2 {
            match warm_allocs {
                None => warm_allocs = Some(allocs),
                Some(w) => assert_eq!(
                    allocs, w,
                    "round {round}: warm batched serving allocated tensors"
                ),
            }
        }
    }

    let st = server.stats();
    assert_eq!(st.submitted, (rounds * (burst + 1)) as u64);
    assert_eq!(st.completed + st.errors, st.submitted, "zero lost tickets: {st:?}");
    if !chaos {
        assert_eq!(st.errors, 0);
        assert!(
            st.batched > 0,
            "blocked same-key bursts never engaged the fused batch path: {st:?}"
        );
        assert!(st.coalesced > 0, "followers must be marked coalesced: {st:?}");
    }
}

#[test]
fn mixed_key_traffic_never_mis_batches() {
    // Interleaved traffic over every key in the mixed workload through a
    // single worker (maximum coalescing opportunity): fusion may only
    // group same-key neighbours, so every reply must match its own key's
    // serial reference — any cross-key grouping would either diverge
    // bitwise or fail shape validation loudly.
    let work = mixed_workload();
    let inputs: Vec<Arc<Vec<Tensor>>> =
        (0..work.len()).map(|i| inputs_for(&work[i].1, 4200 + 100 * i as u64)).collect();
    let reference: Vec<Tensor> = {
        let s = Session::builder().ranks(4).build().unwrap();
        work.iter()
            .zip(&inputs)
            .map(|((expr, shapes), ins)| {
                s.compile(expr, shapes).unwrap().run(ins).unwrap().output
            })
            .collect()
    };

    let session = Session::builder().ranks(4).build().unwrap();
    let server = Server::builder(session).workers(1).queue_capacity(64).build();
    let chaos = faults_active();
    let mut tickets = Vec::new();
    for round in 0..4 {
        // Alternate keys request-by-request, plus doubled submissions on
        // even rounds so same-key pairs sit adjacent in the queue and
        // DO fuse — mis-batching would cross keys right next door.
        for (i, ((expr, shapes), ins)) in work.iter().zip(&inputs).enumerate() {
            let reps = if round % 2 == 0 { 2 } else { 1 };
            for _ in 0..reps {
                let ticket = server
                    .submit(ServeRequest {
                        tenant: "mixed".into(),
                        expr: (*expr).into(),
                        shapes: shapes.clone(),
                        inputs: Arc::clone(ins),
                        dest: Tensor::zeros(&Server::output_dims(expr, shapes).unwrap()),
                    })
                    .unwrap();
                tickets.push((i, ticket));
            }
        }
    }
    for (i, ticket) in tickets {
        match ticket.wait() {
            Ok(reply) => assert!(
                reply.output.allclose(&reference[i], 0.0, 0.0),
                "{}: reply diverged from its own key's reference (mis-batch?)",
                work[i].0
            ),
            Err(e) if chaos && e.is_retryable() => {}
            Err(e) => panic!("request failed outside injected faults: {e}"),
        }
    }
    let st = server.stats();
    assert_eq!(st.completed + st.errors, st.submitted, "zero lost tickets: {st:?}");
}

#[test]
fn shape_invalid_batch_member_fails_typed_without_poisoning_batch_mates() {
    // Direct `Program::run_batch_into` with a poisoned member: the
    // shape-invalid dest must fail with a typed `Error::Shape` while its
    // batch-mates complete bitwise identical to serial references.
    // (`Server::submit` rejects bad dests at admission, so this seam is
    // only reachable through the API-level batch entry.)
    let expr = "ijk,ja,ka->ia";
    let shapes = vec![vec![12, 10, 8], vec![10, 4], vec![8, 4]];
    let ins: Vec<Arc<Vec<Tensor>>> =
        (0..3).map(|k| inputs_for(&shapes, 6400 + 10 * k as u64)).collect();
    let reference: Vec<Tensor> = {
        let s = Session::builder().ranks(4).build().unwrap();
        let mut prog = s.compile(expr, &shapes).unwrap();
        ins.iter()
            .map(|i| {
                let mut out = Tensor::zeros(&prog.output_dims());
                prog.run_into(i, &mut out).unwrap();
                out
            })
            .collect()
    };

    let session = Session::builder().ranks(4).build().unwrap();
    let mut prog = session.compile(expr, &shapes).unwrap();
    let mut d0 = Tensor::zeros(&prog.output_dims());
    let mut bad = Tensor::zeros(&[3, 3]); // wrong dims on the middle member
    let mut d2 = Tensor::zeros(&prog.output_dims());
    let mut members = vec![
        deinsum::BatchRun::new(&ins[0], &mut d0),
        deinsum::BatchRun::new(&ins[1], &mut bad),
        deinsum::BatchRun::new(&ins[2], &mut d2),
    ];
    let results = prog.run_batch_into(&mut members).unwrap();
    drop(members);
    assert!(results[0].is_ok());
    assert!(
        matches!(results[1], Err(deinsum::Error::Shape(_))),
        "shape-invalid member must fail typed: {:?}",
        results[1]
    );
    assert!(results[2].is_ok());
    assert!(d0.allclose(&reference[0], 0.0, 0.0), "member 0 poisoned by invalid mate");
    assert!(d2.allclose(&reference[2], 0.0, 0.0), "member 2 poisoned by invalid mate");
    let st = prog.stats();
    assert_eq!((st.batch_runs, st.batch_members), (1, 3), "{st:?}");
}

#[test]
fn programs_can_move_across_threads() {
    // Program: Send — compile on one thread, run on another, hand the
    // result back.  (Compile-time guarantee exercised at runtime.)
    let session = Session::builder().ranks(2).build().unwrap();
    let shapes = vec![vec![10, 8], vec![8, 6]];
    let mut prog = session.compile("ij,jk->ik", &shapes).unwrap();
    let ins = inputs_for(&shapes, 31);
    let here = prog.run(&ins).unwrap().output;
    let there = std::thread::spawn(move || {
        let out = prog.run(&ins).unwrap().output;
        (prog, out)
    })
    .join()
    .unwrap();
    assert!(here.allclose(&there.1, 0.0, 0.0));
    // And back again.
    let mut prog = there.0;
    let ins2 = inputs_for(&shapes, 31);
    assert!(prog.run(&ins2).unwrap().output.allclose(&here, 0.0, 0.0));
}
