//! Integration tests for the `Session`/`Program` front door (the 0.5.0
//! handle API, thread-safe since 0.6.0): plan-cache behavior,
//! steady-state recycling through the unified `RunStats`, and the
//! private-summed-index pre-reduction.  (The deprecated `Coordinator`
//! wrapper and its equivalence test were removed in 0.6.0; the
//! malformed-plan execution test moved to `coordinator`'s unit tests,
//! which can still drive a hand-corrupted `Plan`.  Concurrency coverage
//! lives in `tests/serving.rs`.)

use deinsum::planner::PlannerConfig;
use deinsum::tensor::contract;
use deinsum::{Error, ExecBackend, Session, Tensor};

/// The paper's §II worked example, small enough for tests.
const WORKED: &str = "ijk,ja,ka,al->il";

fn worked_shapes(n: usize, r: usize) -> Vec<Vec<usize>> {
    vec![vec![n, n, n], vec![n, r], vec![n, r], vec![r, n]]
}

fn random_inputs(shapes: &[Vec<usize>], seed: u64) -> Vec<Tensor> {
    shapes
        .iter()
        .enumerate()
        .map(|(i, s)| Tensor::random(s, seed + i as u64))
        .collect()
}

#[test]
fn recompiling_identical_spec_is_a_counted_cache_hit() {
    // The acceptance pin: the second compile of an identical spec is a
    // cache hit that skips planning — counter-asserted, and the two
    // programs share the very same Plan allocation.
    let shapes = worked_shapes(12, 6);
    let session = Session::builder().ranks(8).build().unwrap();
    let p1 = session.compile(WORKED, &shapes).unwrap();
    assert_eq!(session.cache_stats().misses, 1);
    assert_eq!(session.cache_stats().hits, 0);
    let p2 = session.compile(WORKED, &shapes).unwrap();
    assert_eq!(session.cache_stats().misses, 1, "identical spec must not re-plan");
    assert_eq!(session.cache_stats().hits, 1);
    assert!(
        std::ptr::eq(p1.plan(), p2.plan()),
        "a cache hit must share the cached Plan"
    );
    // Different shapes are a different program: a counted miss.
    let other = worked_shapes(14, 6);
    let p3 = session.compile(WORKED, &other).unwrap();
    assert_eq!(session.cache_stats().misses, 2, "different shapes must re-plan");
    assert!(!std::ptr::eq(p1.plan(), p3.plan()));
    // Different rank count too.
    session.compile_on(WORKED, &shapes, 4).unwrap();
    assert_eq!(session.cache_stats().misses, 3, "different P must re-plan");
}

#[test]
fn program_reruns_are_bitwise_identical_with_flat_unified_allocs() {
    let shapes = worked_shapes(16, 8);
    let inputs = random_inputs(&shapes, 100);
    // Small analysis S forces the two-term split (redistribution on the
    // hot path).
    let cfg = PlannerConfig { s_elements: 64.0, ..Default::default() };
    let session = Session::builder().ranks(8).planner(cfg).build().unwrap();
    let mut prog = session.compile(WORKED, &shapes).unwrap();
    let first = prog.run(&inputs).unwrap();
    // Warm every path, including the recycled-output gather.
    let mut out = Tensor::zeros(&prog.output_dims());
    prog.run_into(&inputs, &mut out).unwrap();
    assert!(out.allclose(&first.output, 0.0, 0.0), "run_into must match run bitwise");
    let warm = prog.stats();
    assert_eq!(warm.runs, 2);
    for _ in 0..3 {
        prog.run_into(&inputs, &mut out).unwrap();
        assert!(out.allclose(&first.output, 0.0, 0.0), "rerun must be bitwise stable");
    }
    let after = prog.stats();
    // The unified figure includes the session-wide engine pool, whose
    // high-water mark is only deterministic on the sequential sim
    // backend; the per-program tensor counters must be flat everywhere.
    if ExecBackend::from_env() == ExecBackend::Sim {
        assert_eq!(
            after.allocs(),
            warm.allocs(),
            "warm run_into reruns must allocate nothing ({warm:?} -> {after:?})"
        );
    }
    assert_eq!(
        after.tensor_allocs(),
        warm.tensor_allocs(),
        "warm run_into reruns must allocate no tensors ({warm:?} -> {after:?})"
    );
    assert!(after.reuses() > warm.reuses(), "reruns must recycle buffers");
    assert_eq!(after.runs, 5);
}

#[test]
fn run_into_matches_run_for_permuted_outputs() {
    // Whatever final layout the planner picks, the recycled-gather path
    // must agree with the allocating one bitwise (covers both the
    // direct-assemble and the permute-staging arm).
    for expr in ["ij,jk->ik", "ij,jk->ki", "ijk,ja,ka->ai"] {
        let lhs = expr.split("->").next().unwrap();
        let shapes: Vec<Vec<usize>> = lhs
            .split(',')
            .map(|s| {
                s.chars()
                    .map(|c| match c {
                        'i' => 12,
                        'j' => 10,
                        'k' => 8,
                        _ => 6,
                    })
                    .collect()
            })
            .collect();
        let inputs = random_inputs(&shapes, 200);
        let session = Session::builder().ranks(4).build().unwrap();
        let mut prog = session.compile(expr, &shapes).unwrap();
        let rep = prog.run(&inputs).unwrap();
        let mut out = Tensor::random(&prog.output_dims(), 999); // dirty dest
        prog.run_into(&inputs, &mut out).unwrap();
        assert!(out.allclose(&rep.output, 0.0, 0.0), "{expr}");
        // Shape-checked: a wrong destination is a typed error.
        let mut bad = Tensor::zeros(&[3, 3]);
        assert!(matches!(
            prog.run_into(&inputs, &mut bad),
            Err(Error::Shape(_))
        ));
    }
}

#[test]
fn private_summed_index_routes_through_recycled_scratch() {
    // `ijk,ka->ia` sums away `j`, which is private to the first operand:
    // the run loop must pre-reduce it through the counted local scratch
    // table (the last documented steady-state allocation exception,
    // now closed) and still match the serial oracle.
    let shapes = vec![vec![10, 7, 8], vec![8, 5]];
    let inputs = random_inputs(&shapes, 400);
    let session = Session::builder().ranks(4).build().unwrap();
    let mut prog = session.compile("ijk,ka->ia", &shapes).unwrap();
    let first = prog.run(&inputs).unwrap();
    let want = contract::einsum2(
        &inputs[0],
        &['i', 'j', 'k'],
        &inputs[1],
        &['k', 'a'],
        &['i', 'a'],
    )
    .unwrap();
    assert!(
        first.output.allclose(&want, 1e-3, 1e-3),
        "rel err {}",
        first.output.rel_error(&want)
    );
    prog.run(&inputs).unwrap();
    let warm = prog.stats();
    assert!(
        warm.local_scratch.reuses > 0,
        "second run must recycle pre-reduction buffers ({warm:?})"
    );
    for _ in 0..3 {
        let rep = prog.run(&inputs).unwrap();
        assert!(rep.output.allclose(&first.output, 0.0, 0.0));
    }
    let after = prog.stats();
    assert_eq!(
        after.local_scratch.allocs, warm.local_scratch.allocs,
        "steady-state pre-reduction must not allocate ({warm:?} -> {after:?})"
    );
    assert_eq!(after.store.dest_allocs, warm.store.dest_allocs);
    assert_eq!(after.store.out_allocs, warm.store.out_allocs);
    // Engine-pool flatness is only deterministic on the sequential sim
    // backend (mp rank threads share the pool concurrently).
    if ExecBackend::from_env() == ExecBackend::Sim {
        assert_eq!(
            after.engine_scratch.allocs, warm.engine_scratch.allocs,
            "engine packing/fold scratch must stay flat in steady state"
        );
    }
}

#[test]
fn malformed_plan_error_formats_with_term_context() {
    // Execution-time coverage of MalformedPlan (which needs to inject a
    // corrupted Plan into the run loop) lives in `coordinator`'s unit
    // tests since the deprecated wrapper's removal; the public surface
    // here is the error type itself.
    let e = Error::malformed_plan("term0", "boom");
    assert_eq!(e.to_string(), "malformed plan (term term0): boom");
    assert!(matches!(e, Error::MalformedPlan { .. }));
}
