//! Property-based tests on the coordinator invariants (hand-rolled
//! generator — the offline registry has no proptest; shrinking is traded
//! for printing the failing seed/case, which is reproducible because all
//! randomness is seeded xorshift).
//!
//! Invariants exercised, across randomized einsums / extents / rank
//! counts:
//!
//! 1. **Distribution correctness** — the distributed result equals the
//!    serial oracle (routing + batching + replication + reduction +
//!    redistribution compose to the identity on the math).
//! 2. **Conservation** — redistribution plans move exactly the tensor's
//!    volume (no element lost or duplicated per destination block).
//! 3. **Grid validity** — every planned grid factors P exactly and never
//!    over-splits an extent.
//! 4. **Fusion sanity** — fused plans never do more modeled I/O than the
//!    unfused baseline.

use deinsum::baseline::plan_baseline;
use deinsum::einsum::EinsumSpec;
use deinsum::planner::{plan, PlannerConfig};
use deinsum::redist;
use deinsum::tensor::{contract, Tensor};
use deinsum::Session;

/// Tiny deterministic PRNG (xorshift64*).
struct Rng(u64);
impl Rng {
    fn new(seed: u64) -> Self {
        Rng(seed.wrapping_mul(0x9E3779B97F4A7C15) | 1)
    }
    fn next(&mut self) -> u64 {
        self.0 ^= self.0 << 13;
        self.0 ^= self.0 >> 7;
        self.0 ^= self.0 << 17;
        self.0
    }
    fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + (self.next() as usize) % (hi - lo + 1)
    }
    fn pick<'a, T>(&mut self, v: &'a [T]) -> &'a T {
        &v[self.range(0, v.len() - 1)]
    }
}

/// Serial oracle: run the optimized path globally with einsum2.
fn oracle(spec: &EinsumSpec, inputs: &[Tensor]) -> Tensor {
    let path = deinsum::contraction::optimize(spec).unwrap();
    let mut table: std::collections::BTreeMap<usize, (Tensor, Vec<char>)> =
        Default::default();
    for (i, t) in inputs.iter().enumerate() {
        table.insert(i, (t.clone(), spec.inputs[i].clone()));
    }
    let mut last = 0;
    for op in &path.ops {
        let (a, ai) = table[&op.input_ids[0]].clone();
        let out = if op.input_ids.len() == 2 {
            let (b, bi) = table[&op.input_ids[1]].clone();
            contract::einsum2(&a, &ai, &b, &bi, &op.output).unwrap()
        } else {
            // unary permute/reduce
            let mut t = a;
            let mut idx = ai;
            while let Some(d) = idx.iter().position(|c| !op.output.contains(c)) {
                t = contract::reduce_mode(&t, d);
                idx.remove(d);
            }
            if idx != op.output {
                let perm: Vec<usize> = op
                    .output
                    .iter()
                    .map(|c| idx.iter().position(|d| d == c).unwrap())
                    .collect();
                t = t.permute(&perm);
            }
            t
        };
        table.insert(op.output_id, (out, op.output.clone()));
        last = op.output_id;
    }
    let (t, idx) = table[&last].clone();
    if idx == spec.output {
        t
    } else {
        let perm: Vec<usize> = spec
            .output
            .iter()
            .map(|c| idx.iter().position(|d| d == c).unwrap())
            .collect();
        t.permute(&perm)
    }
}

/// Random benchmark-family einsum with random small extents.
fn random_case(rng: &mut Rng) -> (String, Vec<Vec<usize>>) {
    let exprs = [
        "ij,jk->ik",
        "ij,jk,kl->il",
        "ijk,ja,ka->ia",
        "ijk,ia,ka->ja",
        "ijk,ia,ja->ka",
        "ijk,ja,ka,al->il",
        "ijkl,ja,ka,la->ia",
    ];
    let expr = (*rng.pick(&exprs)).to_string();
    let mut ext: std::collections::BTreeMap<char, usize> = Default::default();
    for c in expr.chars().filter(|c| c.is_ascii_alphabetic()) {
        ext.entry(c).or_insert_with(|| rng.range(3, 14));
    }
    let lhs = expr.split("->").next().unwrap().to_string();
    let shapes: Vec<Vec<usize>> =
        lhs.split(',').map(|s| s.chars().map(|c| ext[&c]).collect()).collect();
    (expr, shapes)
}

#[test]
fn property_distributed_equals_oracle() {
    // One session for all trials: the engine and the plan cache are
    // shared, so repeated (expr, shapes, p) draws hit the cache.
    let session = Session::builder().build().unwrap();
    let mut rng = Rng::new(0xD315);
    for trial in 0..40 {
        let (expr, shapes) = random_case(&mut rng);
        let p = *rng.pick(&[1usize, 2, 3, 4, 6, 8]);
        let spec = EinsumSpec::parse(&expr, &shapes).unwrap();
        let mut prog = match session.compile_on(&expr, &shapes, p) {
            Ok(prog) => prog,
            Err(e) => panic!("trial {trial} ({expr}, P={p}): compile failed: {e}"),
        };
        let inputs: Vec<Tensor> = shapes
            .iter()
            .enumerate()
            .map(|(i, s)| Tensor::random(s, trial * 31 + i as u64))
            .collect();
        let rep = prog
            .run(&inputs)
            .unwrap_or_else(|e| panic!("trial {trial} ({expr}, P={p}): {e}"));
        let want = oracle(&spec, &inputs);
        assert!(
            rep.output.allclose(&want, 1e-3, 1e-3),
            "trial {trial}: {expr} P={p} shapes {shapes:?}: rel err {}",
            rep.output.rel_error(&want)
        );
    }
}

#[test]
fn property_baseline_equals_oracle() {
    let session = Session::builder().build().unwrap();
    let mut rng = Rng::new(0xBA5E);
    for trial in 0..25 {
        let (expr, shapes) = random_case(&mut rng);
        let p = *rng.pick(&[1usize, 2, 4, 8]);
        let spec = EinsumSpec::parse(&expr, &shapes).unwrap();
        let inputs: Vec<Tensor> = shapes
            .iter()
            .enumerate()
            .map(|(i, s)| Tensor::random(s, trial * 37 + i as u64))
            .collect();
        let rep = session
            .compile_baseline_on(&expr, &shapes, p)
            .and_then(|mut prog| prog.run(&inputs))
            .unwrap_or_else(|e| panic!("trial {trial} ({expr}, P={p}): {e}"));
        let want = oracle(&spec, &inputs);
        assert!(
            rep.output.allclose(&want, 1e-3, 1e-3),
            "trial {trial}: {expr} P={p}: rel err {}",
            rep.output.rel_error(&want)
        );
    }
}

#[test]
fn property_redistribution_conserves_elements() {
    use deinsum::dist::TensorDist;
    use deinsum::grid::ProcessGrid;
    let mut rng = Rng::new(0x5EED);
    for trial in 0..60 {
        let nd = rng.range(1, 3);
        let extents: Vec<usize> = (0..nd).map(|_| rng.range(4, 20)).collect();
        let mk = |rng: &mut Rng, extents: &[usize]| {
            let gdims: Vec<usize> =
                extents.iter().map(|&e| [1usize, 2, 3, 4][rng.range(0, 3)].min(e)).collect();
            let g = ProcessGrid::new(&gdims).unwrap();
            let all: Vec<usize> = (0..extents.len()).collect();
            TensorDist::new(extents, &g, &all).unwrap()
        };
        let src = mk(&mut rng, &extents);
        let dst = mk(&mut rng, &extents);
        let rp = redist::plan(&src, &dst).unwrap();
        // Each destination block must be covered exactly once.
        let total: usize = extents.iter().product();
        let covered: usize = rp
            .messages
            .iter()
            .map(|m| m.volume())
            .sum::<usize>()
            / dst_replicas(&dst);
        assert_eq!(
            covered, total,
            "trial {trial}: extents {extents:?} src {:?} dst {:?}",
            src.dist.grid, dst.dist.grid
        );
        // And the data must actually round-trip.
        let global = Tensor::random(&extents, trial);
        let src_bufs: Vec<Tensor> = (0..src.grid.size())
            .map(|r| {
                let (off, _) = src.block_for_rank(r);
                global.block(&off, &src.local_dims())
            })
            .collect();
        let mut out: Vec<Tensor> = (0..src.grid.size().max(dst.grid.size()))
            .map(|_| Tensor::zeros(&dst.local_dims()))
            .collect();
        redist::execute_into(&rp, &src_bufs, &mut out);
        for r in 0..dst.grid.size() {
            let (off, size) = dst.block_for_rank(r);
            let want = global.block(&off, &size);
            let got = out[r].block(&vec![0; size.len()], &size);
            assert!(got.allclose(&want, 0.0, 0.0), "trial {trial} rank {r}");
        }
    }
}

fn dst_replicas(dst: &deinsum::dist::TensorDist) -> usize {
    dst.grid.size() / dst.n_blocks()
}

#[test]
fn property_grids_factor_p_exactly() {
    let mut rng = Rng::new(0x6B1D);
    for trial in 0..40 {
        let (expr, shapes) = random_case(&mut rng);
        let p = rng.range(1, 12);
        let spec = EinsumSpec::parse(&expr, &shapes).unwrap();
        let Ok(pl) = plan(&spec, p, &PlannerConfig::default()) else {
            continue;
        };
        for t in &pl.terms {
            assert_eq!(t.grid.size(), p, "trial {trial}: {expr} P={p}");
            for (d, (&g, &n)) in t.grid.dims().iter().zip(&t.extents).enumerate() {
                assert!(g <= n, "trial {trial}: grid dim {d} over-split ({g} > {n})");
            }
        }
    }
}

#[test]
fn property_fused_q_never_worse() {
    let mut rng = Rng::new(0xF0500);
    for _ in 0..20 {
        let (expr, mut shapes) = random_case(&mut rng);
        // Inflate to sizes where fusion matters.
        for s in &mut shapes {
            for d in s.iter_mut() {
                *d *= 64;
            }
        }
        let spec = EinsumSpec::parse(&expr, &shapes).unwrap();
        let fused = plan(&spec, 8, &PlannerConfig::default()).unwrap();
        let unfused = plan_baseline(&spec, 8).unwrap();
        assert!(
            fused.total_q <= unfused.total_q * 1.0001,
            "{expr}: fused Q {} > unfused {}",
            fused.total_q,
            unfused.total_q
        );
    }
}
