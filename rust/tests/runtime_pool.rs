//! Integration tests for the persistent work-stealing runtime: the
//! pool-dispatched shared-packing GEMM / fused MTTKRP / transpose against
//! the retained serial oracles, bitwise determinism across thread counts
//! (`DEINSUM_NUM_THREADS=1` vs `=8` feed exactly the `threads` field
//! varied here — the env var is read once into `KernelConfig`), and pool
//! persistence across kernel invocations.

use deinsum::runtime::pool;
use deinsum::tensor::kernel::{self, KernelConfig, ScratchPool};
use deinsum::tensor::{contract, transpose, Tensor};

fn gemm_scalar(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0.0f32; m * n];
    contract::gemm_scalar_into(a, b, &mut c, m, k, n);
    c
}

/// Shapes chosen to drive every parallel macro-loop regime above the
/// serial cutoff: square, skinny-M/wide-N (jr-chunk splitting), tall-M/
/// narrow-N, and ragged everything.
const GEMM_SHAPES: [(usize, usize, usize); 5] =
    [(128, 128, 128), (8, 96, 700), (700, 96, 8), (150, 70, 90), (37, 300, 41)];

#[test]
fn pool_gemm_matches_scalar_oracle() {
    let pool = ScratchPool::new();
    let cfg = KernelConfig { mc: 32, kc: 32, nc: 48, threads: 8 }.normalized();
    for &(m, k, n) in &GEMM_SHAPES {
        let a = Tensor::random(&[m, k], (m * 7 + n) as u64);
        let b = Tensor::random(&[k, n], (k * 3 + m) as u64);
        let want = gemm_scalar(a.data(), b.data(), m, k, n);
        let mut c = vec![0.0f32; m * n];
        kernel::gemm_into_with(&cfg, &pool, a.data(), b.data(), &mut c, m, k, n);
        for (i, (&g, &w)) in c.iter().zip(&want).enumerate() {
            assert!(
                (g - w).abs() <= 1e-3 + 1e-3 * w.abs(),
                "({m},{k},{n}) elem {i}: {g} vs {w}"
            );
        }
    }
}

#[test]
fn gemm_bitwise_deterministic_across_thread_counts() {
    // Same blocks => same per-element reduction order regardless of the
    // thread count or which worker claims a tile: results are bitwise
    // identical, so DEINSUM_NUM_THREADS=1 and =8 agree exactly.
    let pool = ScratchPool::new();
    let base = KernelConfig { mc: 32, kc: 32, nc: 48, threads: 1 }.normalized();
    for &(m, k, n) in &GEMM_SHAPES {
        let a = Tensor::random(&[m, k], (m + k * 5) as u64);
        let b = Tensor::random(&[k, n], (n + k * 11) as u64);
        let mut c1 = vec![0.0f32; m * n];
        kernel::gemm_into_with(&base, &pool, a.data(), b.data(), &mut c1, m, k, n);
        for threads in [2usize, 8] {
            let mut ct = vec![0.0f32; m * n];
            kernel::gemm_into_with(
                &base.with_threads(threads),
                &pool,
                a.data(),
                b.data(),
                &mut ct,
                m,
                k,
                n,
            );
            assert_eq!(c1, ct, "({m},{k},{n}) threads {threads} diverged bitwise");
        }
    }
}

#[test]
fn mttkrp_bitwise_deterministic_and_matches_two_step() {
    let pool = ScratchPool::new();
    let x = Tensor::random(&[64, 32, 32], 91);
    let fs: Vec<Tensor> =
        (0..3).map(|m| Tensor::random(&[x.dims()[m], 24], 92 + m as u64)).collect();
    let frefs: Vec<&Tensor> = fs.iter().collect();
    let base = KernelConfig::default().serial();
    for mode in 0..3 {
        let serial = contract::mttkrp_with(&base, &pool, &x, &frefs, mode).unwrap();
        for threads in [2usize, 8] {
            let par = contract::mttkrp_with(
                &base.with_threads(threads),
                &pool,
                &x,
                &frefs,
                mode,
            )
            .unwrap();
            assert_eq!(
                serial.data(),
                par.data(),
                "mode {mode} threads {threads} diverged bitwise"
            );
        }
        let two = contract::mttkrp_two_step(&x, &frefs, mode).unwrap();
        assert!(serial.allclose(&two, 1e-2, 1e-3), "mode {mode} vs two-step oracle");
    }
}

#[test]
fn transpose_bitwise_deterministic_across_thread_counts() {
    let base = KernelConfig::default();
    for (dims, perm) in [
        (vec![64usize, 64, 32], vec![2usize, 1, 0]), // blocked 2D path
        (vec![64, 64, 32], vec![1, 0, 2]),           // inner-run fast path
        (vec![600, 512], vec![1, 0]),                // matrix transpose
    ] {
        let t = Tensor::random(&dims, 401);
        let serial = transpose::permute_with(&base.serial(), &t, &perm);
        for threads in [2usize, 8] {
            let par = transpose::permute_with(&base.with_threads(threads), &t, &perm);
            assert_eq!(serial, par, "{dims:?} {perm:?} threads {threads}");
        }
    }
}

#[test]
fn pool_workers_persist_across_kernel_invocations() {
    // Force the pool to its in-process maximum (8 participants => 7
    // workers), then verify repeated kernel invocations dispatch jobs to
    // the same worker set — the whole point of the persistent runtime.
    pool::global().run(8, 64, &|_t| {});
    let w0 = pool::global().stats().workers;
    assert!(w0 <= 7, "8 participants need at most 7 workers, got {w0}");
    let pool_ = ScratchPool::new();
    let cfg = KernelConfig { mc: 32, kc: 32, nc: 32, threads: 8 }.normalized();
    let a = Tensor::random(&[256, 128], 5);
    let b = Tensor::random(&[128, 256], 6);
    let jobs0 = pool::global().stats().jobs;
    let mut c = vec![0.0f32; 256 * 256];
    for _ in 0..3 {
        c.fill(0.0);
        kernel::gemm_into_with(&cfg, &pool_, a.data(), b.data(), &mut c, 256, 128, 256);
    }
    let s = pool::global().stats();
    assert!(s.jobs > jobs0, "parallel kernels must dispatch pool jobs");
    assert_eq!(s.workers, w0, "kernel invocations must not spawn new workers");
}

#[test]
fn pool_gemm_steady_state_is_alloc_free() {
    // The shared-packing parallel path draws one shared B panel plus one
    // A panel per in-flight task from the scratch pool; pre-seed the
    // high-water mark, then repeated runs must be served entirely from
    // the free lists.
    let pool_ = ScratchPool::new();
    let cfg = KernelConfig { mc: 32, kc: 32, nc: 32, threads: 8 }.normalized();
    {
        let _a: Vec<_> = (0..10).map(|_| pool_.take(cfg.mc * cfg.kc)).collect();
        let _b: Vec<_> = (0..2).map(|_| pool_.take(cfg.kc * cfg.nc)).collect();
    }
    let a = Tensor::random(&[128, 96], 7);
    let b = Tensor::random(&[96, 128], 8);
    let mut c = vec![0.0f32; 128 * 128];
    let warm = pool_.stats().allocs;
    for _ in 0..5 {
        c.fill(0.0);
        kernel::gemm_into_with(&cfg, &pool_, a.data(), b.data(), &mut c, 128, 96, 128);
    }
    let after = pool_.stats();
    assert_eq!(after.allocs, warm, "steady-state shared-pack gemm allocated");
    assert!(after.takes > 0, "gemm must route packing buffers through the pool");
}

#[test]
fn scoped_baseline_produces_identical_results() {
    // The retained spawn-per-region dispatch is a drop-in for the pool:
    // same task decomposition, same outputs (it backs the bench's
    // per-step-spawn baseline).
    use std::sync::atomic::{AtomicU64, Ordering};
    let from_pool = AtomicU64::new(0);
    let from_scoped = AtomicU64::new(0);
    pool::global().run(4, 100, &|t| {
        from_pool.fetch_add((t * t) as u64, Ordering::Relaxed);
    });
    pool::run_scoped(4, 100, &|t| {
        from_scoped.fetch_add((t * t) as u64, Ordering::Relaxed);
    });
    assert_eq!(from_pool.load(Ordering::Relaxed), from_scoped.load(Ordering::Relaxed));
}
