//! Fault-tolerance acceptance suite for the 0.7.0 serving stack: under
//! a deterministic [`FaultPlan`] injecting worker panics, transient run
//! failures and latency into a mixed-traffic loop, every submitted
//! ticket resolves (filled or typed error — none hang), successful
//! results stay bitwise identical to a fault-free serial run, the
//! `ServeStats` restart/shed/timeout/retry counters match the injected
//! plan exactly, and steady-state tensor allocations are flat again
//! after recovery.
//!
//! Every server in this file installs an *explicit* plan via
//! `ServerBuilder::fault_plan`, so the suite is deterministic whether or
//! not the CI chaos leg's `DEINSUM_FAULT_SEED` is set in the
//! environment (the env-seeded plan only arms `serve.*` sites, which an
//! explicit plan overrides; the serial reference paths below touch only
//! `engine.*`/`run_plan.*` sites, which the seeded plan never arms).

use std::sync::Arc;
use std::time::Duration;

use deinsum::fault::site;
use deinsum::{Error, FaultPlan, ServeRequest, Server, Session, Tensor, Ticket};

/// The mixed workload from `tests/serving.rs`: eight distinct program
/// keys spanning MTTKRP (all modes, one permuted), TTMc, GEMM and a
/// chain.
fn mixed_workload() -> Vec<(&'static str, Vec<Vec<usize>>)> {
    let n = 12usize;
    let r = 4usize;
    vec![
        ("ijk,ja,ka->ia", vec![vec![n, n, n], vec![n, r], vec![n, r]]),
        ("ijk,ia,ka->ja", vec![vec![n, n, n], vec![n, r], vec![n, r]]),
        ("ijk,ia,ja->ka", vec![vec![n, n, n], vec![n, r], vec![n, r]]),
        ("ijk,ja,ka->ai", vec![vec![n, n, n], vec![n, r], vec![n, r]]),
        ("ijkl,jb,kc,ld->ibcd", vec![vec![6, 6, 6, 6], vec![6, 3], vec![6, 3], vec![6, 3]]),
        ("ij,jk->ik", vec![vec![16, 12], vec![12, 8]]),
        ("ij,jk->ki", vec![vec![16, 12], vec![12, 8]]),
        ("ij,jk,kl->il", vec![vec![10, 8], vec![8, 12], vec![12, 6]]),
    ]
}

fn inputs_for(shapes: &[Vec<usize>], seed: u64) -> Arc<Vec<Tensor>> {
    Arc::new(
        shapes
            .iter()
            .enumerate()
            .map(|(i, s)| Tensor::random(s, seed + i as u64))
            .collect(),
    )
}

/// Fault-free serial references on an independent session (identical
/// settings → identical plans → bitwise-identical outputs).
fn serial_references(
    ranks: usize,
    work: &[(&'static str, Vec<Vec<usize>>)],
    inputs: &[Arc<Vec<Tensor>>],
) -> Vec<Tensor> {
    let s = Session::builder().ranks(ranks).build().unwrap();
    work.iter()
        .zip(inputs)
        .map(|((expr, shapes), ins)| s.compile(expr, shapes).unwrap().run(ins).unwrap().output)
        .collect()
}

fn request_for(
    tenant: &str,
    (expr, shapes): &(&'static str, Vec<Vec<usize>>),
    ins: &Arc<Vec<Tensor>>,
) -> ServeRequest {
    ServeRequest {
        tenant: tenant.into(),
        expr: (*expr).into(),
        shapes: shapes.clone(),
        inputs: Arc::clone(ins),
        dest: Tensor::zeros(&Server::output_dims(expr, shapes).unwrap()),
    }
}

/// The acceptance pin: 8 workers, two tenants, three rounds of mixed
/// traffic under explicit worker panics + transient run failures +
/// injected latency.  With `max_retries` at least the total number of
/// error-class faults, no request can exhaust its budget, so every
/// ticket must resolve `Ok` and bitwise-match the serial reference.
#[test]
fn chaos_mixed_traffic_resolves_every_ticket_bitwise_identical() {
    let work = mixed_workload();
    let inputs: Vec<Arc<Vec<Tensor>>> =
        (0..work.len()).map(|i| inputs_for(&work[i].1, 9000 + 100 * i as u64)).collect();
    let reference = serial_references(4, &work, &inputs);

    // 4 transients + 2 panics = 6 error-class fault events.  All ticks
    // are below the chaos phase's guaranteed site traffic (48 requests →
    // ≥ 48 ticks at serve.run and serve.worker), so every rule fires
    // during the chaos phase and none later.
    let plan = FaultPlan::new()
        .transient_at(site::SERVE_RUN, &[2, 9, 17, 26])
        .panic_at(site::SERVE_WORKER, &[5, 19])
        .latency_at(site::SERVE_WORKER, Duration::from_micros(200), &[3, 11]);
    let session = Session::builder().ranks(4).build().unwrap();
    let server = Server::builder(session)
        .workers(8)
        .queue_capacity(32)
        .max_retries(6)
        .fault_plan(plan.clone())
        .build();

    let submit_round = |tenant: &str| -> Vec<Ticket> {
        work.iter()
            .zip(&inputs)
            .map(|(key, ins)| server.submit(request_for(tenant, key, ins)).unwrap())
            .collect()
    };

    // Chaos phase: 3 rounds × 2 tenants × 8 keys = 48 requests in
    // flight while every scheduled fault fires.
    let mut rounds = Vec::new();
    for _ in 0..3 {
        for tenant in ["tenant-a", "tenant-b"] {
            rounds.push(submit_round(tenant));
        }
    }
    for tickets in rounds {
        for (ticket, want) in tickets.into_iter().zip(&reference) {
            let reply = ticket.wait().expect("budget covers every injected fault");
            assert!(
                reply.output.allclose(want, 0.0, 0.0),
                "served output diverged from fault-free serial reference"
            );
        }
    }

    let fired_panics = plan.fired(site::SERVE_WORKER).panics;
    let fired_transients = plan.fired(site::SERVE_RUN).transients;
    let fired_latencies = plan.fired(site::SERVE_WORKER).latencies;
    assert_eq!(fired_panics, 2, "both worker-panic ticks were reached");
    assert_eq!(fired_transients, 4, "all transient ticks were reached");
    assert_eq!(fired_latencies, 2, "both latency ticks were reached");

    let st = server.stats();
    assert_eq!(st.completed, 48, "every chaos-phase request completed: {st:?}");
    assert_eq!(st.errors, 0);
    assert_eq!(st.in_flight, 0);
    // The recovery counters match the injected plan exactly: one
    // supervisor restart per uncontained panic, no sheds and no
    // timeouts (the plan injects neither), at least one retry per
    // transient (worker crashes requeue whatever they held, so retries
    // may exceed the transient count).
    assert_eq!(st.restarts, fired_panics, "restarts must match injected panics: {st:?}");
    assert_eq!(st.shed, 0);
    assert_eq!(st.timeouts, 0);
    assert!(
        st.retries >= fired_transients,
        "each injected transient forces a retry: {st:?}"
    );

    // Recovery: all scheduled ticks are spent, so traffic is now clean.
    // Two re-warm rounds (crashed workers rebuild their LRUs from cached
    // plans and every recycled path refills its buffers, as in
    // tests/serving.rs), then steady state must be allocation-flat again.
    for _ in 0..2 {
        for ticket in submit_round("rewarm") {
            ticket.wait().unwrap();
        }
    }
    let warm = server.stats();
    assert_eq!(warm.restarts, fired_panics, "no restarts after the last panic tick");
    for _ in 0..2 {
        for ticket in submit_round("steady") {
            ticket.wait().unwrap();
        }
    }
    let after = server.stats();
    assert_eq!(after.errors, 0);
    assert_eq!(
        after.tensor_allocs, warm.tensor_allocs,
        "steady-state allocations must be flat after recovery ({warm:?} -> {after:?})"
    );
    assert!(after.tensor_reuses > warm.tensor_reuses, "recovered steady state recycles");
    assert_eq!(after.restarts, fired_panics);
    assert_eq!(after.completed, warm.completed + 16);
}

/// Satellite: panic containment on the compile path AND the run path,
/// exercised on both the serial and the 8-thread kernel engine (the CI
/// matrix additionally runs this whole suite under
/// `DEINSUM_NUM_THREADS={1,8}`).  A contained panic costs exactly one
/// request a typed error — the pool keeps serving, other tenants'
/// accounting survives, and the supervisor is never involved.
#[test]
fn contained_panics_cost_one_request_across_thread_counts() {
    for threads in [1usize, 8] {
        // Tick 0 of serve.compile: the very first program instantiation
        // panics.  Tick 1 of serve.run: the second run attempt panics.
        // max_retries(0) so the run panic surfaces instead of retrying.
        let plan = FaultPlan::new()
            .panic_at(site::SERVE_COMPILE, &[0])
            .panic_at(site::SERVE_RUN, &[1]);
        let session = Session::builder().ranks(2).threads(threads).build().unwrap();
        let server = Server::builder(session)
            .workers(2)
            .max_retries(0)
            .fault_plan(plan.clone())
            .build();
        let key = ("ij,jk->ik", vec![vec![8, 6], vec![6, 4]]);
        let ins = inputs_for(&key.1, 42);

        // Serial submit/wait so the site tick order is deterministic.
        // 1) compile tick 0 → contained panic → typed error, never
        //    retried (compile failures are deterministic).
        let err = server
            .submit(request_for("victim-compile", &key, &ins))
            .unwrap()
            .wait()
            .expect_err("first compile is scheduled to panic");
        match &err {
            Error::Runtime(m) => assert!(m.contains("panicked"), "{m}"),
            other => panic!("expected contained-panic Runtime error, got {other}"),
        }
        assert!(!err.is_retryable(), "compile failures must never be retried");

        // 2) clean request: compile tick 1, run tick 0 → success.
        let reply = server.submit(request_for("survivor", &key, &ins)).unwrap().wait();
        assert!(reply.is_ok(), "pool must keep serving after a contained compile panic");

        // 3) warm hit, run tick 1 → contained run panic → typed error,
        //    program dropped (possibly inconsistent state).
        let err = server
            .submit(request_for("victim-run", &key, &ins))
            .unwrap()
            .wait()
            .expect_err("second run is scheduled to panic");
        match &err {
            Error::Runtime(m) => assert!(m.contains("panicked"), "{m}"),
            other => panic!("expected contained-panic Runtime error, got {other}"),
        }

        // 4) the dropped program re-instantiates from the cached plan and
        //    serving continues.
        for _ in 0..3 {
            server
                .submit(request_for("survivor", &key, &ins))
                .unwrap()
                .wait()
                .expect("pool must keep serving after a contained run panic");
        }

        let st = server.stats();
        assert_eq!(
            st.restarts, 0,
            "threads={threads}: contained panics must never reach the supervisor: {st:?}"
        );
        assert_eq!(st.errors, 2, "exactly the two victims failed: {st:?}");
        assert_eq!(st.completed, 4);
        assert_eq!(st.in_flight, 0);
        // The untouched tenant's accounting survived both panics.
        let ts = server.tenant_stats("survivor").unwrap();
        assert_eq!((ts.completed, ts.errors), (4, 0), "threads={threads}: {ts:?}");
        assert!(ts.p50_latency_s <= ts.p99_latency_s);
        assert!(ts.p99_latency_s > 0.0, "latency window survived: {ts:?}");
        assert_eq!(plan.fired(site::SERVE_COMPILE).panics, 1);
        assert_eq!(plan.fired(site::SERVE_RUN).panics, 1);
    }
}

/// Transient run failures are retried to success within budget, counted
/// exactly, and the eventual output is bitwise identical to a clean run.
#[test]
fn transient_run_failures_retry_to_success() {
    let key = ("ij,jk->ik", vec![vec![10, 8], vec![8, 6]]);
    let ins = inputs_for(&key.1, 7);
    let want = {
        let s = Session::builder().ranks(2).build().unwrap();
        s.compile(key.0, &key.1).unwrap().run(&ins).unwrap().output
    };

    // First two run attempts fail transiently; the third succeeds.
    let plan = FaultPlan::new().transient_at(site::SERVE_RUN, &[0, 1]);
    let session = Session::builder().ranks(2).build().unwrap();
    let server =
        Server::builder(session).workers(1).max_retries(2).fault_plan(plan.clone()).build();
    let reply = server
        .submit(request_for("t", &key, &ins))
        .unwrap()
        .wait()
        .expect("two retries cover two injected transients");
    assert!(reply.output.allclose(&want, 0.0, 0.0), "retried result must stay bitwise");
    let st = server.stats();
    assert_eq!((st.completed, st.errors, st.retries), (1, 0, 2), "{st:?}");
    assert_eq!(plan.fired(site::SERVE_RUN).transients, 2);
    assert_eq!(st.restarts, 0, "typed transients never involve the supervisor");
}

/// A request whose failures outnumber the retry budget gets the typed
/// transient error back — after exactly `max_retries` counted retries.
#[test]
fn retry_budget_exhaustion_surfaces_the_typed_error() {
    let key = ("ij,jk->ik", vec![vec![8, 6], vec![6, 4]]);
    let ins = inputs_for(&key.1, 11);
    let plan = FaultPlan::new().transient_at(site::SERVE_RUN, &[0, 1, 2]);
    let session = Session::builder().ranks(2).build().unwrap();
    let server =
        Server::builder(session).workers(1).max_retries(2).fault_plan(plan.clone()).build();
    let err = server
        .submit(request_for("t", &key, &ins))
        .unwrap()
        .wait()
        .expect_err("three injected failures beat a budget of two");
    assert!(matches!(err, Error::Transient(_)), "{err}");
    assert!(err.is_retryable(), "the caller may resubmit");
    let st = server.stats();
    assert_eq!((st.completed, st.errors, st.retries), (0, 1, 2), "{st:?}");

    // The server is healthy afterwards: the remaining ticks are spent,
    // so a resubmission succeeds.
    server.submit(request_for("t", &key, &ins)).unwrap().wait().unwrap();
    assert_eq!(server.stats().completed, 1);
}

/// Supervision end to end with exact counter accounting: three
/// scheduled worker panics against one request and a budget of two.
/// The supervisor restarts the incarnation three times; the request is
/// requeued twice (both retries counted) and failed with the typed
/// `WorkerLost` on the third crash — and the pool serves again
/// afterwards.
#[test]
fn worker_crashes_requeue_then_fail_typed_with_exact_counters() {
    let key = ("ij,jk->ik", vec![vec![8, 6], vec![6, 4]]);
    let ins = inputs_for(&key.1, 23);
    let plan = FaultPlan::new().panic_at(site::SERVE_WORKER, &[0, 1, 2]);
    let session = Session::builder().ranks(2).build().unwrap();
    let server =
        Server::builder(session).workers(1).max_retries(2).fault_plan(plan.clone()).build();

    let err = server
        .submit(request_for("t", &key, &ins))
        .unwrap()
        .wait()
        .expect_err("three crashes beat a budget of two");
    match &err {
        Error::WorkerLost(m) => assert!(m.contains("retry budget exhausted"), "{m}"),
        other => panic!("expected WorkerLost, got {other}"),
    }
    assert!(err.is_retryable(), "a fresh incarnation may well serve a resubmission");

    let st = server.stats();
    assert_eq!(st.restarts, 3, "one restart per injected crash: {st:?}");
    assert_eq!(st.retries, 2, "two requeues before the budget ran out: {st:?}");
    assert_eq!((st.completed, st.errors), (0, 1));
    assert_eq!(plan.fired(site::SERVE_WORKER).panics, 3);

    // The fourth incarnation is past every scheduled tick: resubmission
    // succeeds on a rebuilt warm LRU.
    let reply = server.submit(request_for("t", &key, &ins)).unwrap().wait().unwrap();
    assert_eq!(reply.output.dims(), &[8, 4]);
    let st = server.stats();
    assert_eq!((st.completed, st.restarts), (1, 3), "{st:?}");
}

/// Injected latency + a bounded client wait: `wait_timeout` returns the
/// typed deadline error while the worker still finishes the request and
/// fulfills the abandoned slot — one timeout counted, nothing lost,
/// nothing hung.
#[test]
fn injected_latency_trips_wait_timeout_but_loses_nothing() {
    let key = ("ij,jk->ik", vec![vec![8, 6], vec![6, 4]]);
    let ins = inputs_for(&key.1, 31);
    let plan =
        FaultPlan::new().latency_at(site::SERVE_RUN, Duration::from_millis(200), &[0]);
    let session = Session::builder().ranks(2).build().unwrap();
    let server = Server::builder(session).workers(1).fault_plan(plan.clone()).build();

    let ticket = server.submit(request_for("t", &key, &ins)).unwrap();
    let err = ticket
        .wait_timeout(Duration::from_millis(10))
        .expect_err("the injected 200ms stall outlasts a 10ms wait bound");
    assert!(matches!(err, Error::DeadlineExceeded), "{err}");

    // The worker is merely slow, not broken: it completes the request
    // into the abandoned slot.  Poll the server's own accounting.
    let mut waited = Duration::ZERO;
    while server.stats().completed == 0 && waited < Duration::from_secs(5) {
        std::thread::sleep(Duration::from_millis(10));
        waited += Duration::from_millis(10);
    }
    let st = server.stats();
    assert_eq!(st.completed, 1, "the abandoned request still completes: {st:?}");
    assert_eq!(st.errors, 0);
    assert_eq!(st.timeouts, 1, "the abandoned wait is counted: {st:?}");
    assert_eq!(plan.fired(site::SERVE_RUN).latencies, 1);
}

/// The CI chaos leg's invariant, pinned in-process: under the
/// `DEINSUM_FAULT_SEED`-style seeded plan (strided transients, worker
/// panics and latency), a closed mixed-traffic loop completes with
/// **zero lost tickets** — every wait returns, `completed + errors ==
/// submitted`, restarts match fired panics exactly, and every
/// successful result is bitwise identical to the fault-free reference.
#[test]
fn seeded_chaos_plan_loses_no_tickets() {
    let work = mixed_workload();
    let inputs: Vec<Arc<Vec<Tensor>>> =
        (0..work.len()).map(|i| inputs_for(&work[i].1, 13000 + 100 * i as u64)).collect();
    let reference = serial_references(4, &work, &inputs);

    let plan = FaultPlan::seeded(20260808);
    let session = Session::builder().ranks(4).build().unwrap();
    let server = Server::builder(session)
        .workers(8)
        .queue_capacity(32)
        .fault_plan(plan.clone()) // default max_retries, like the CI leg
        .build();

    let mut outcomes = Vec::new();
    for round in 0..4 {
        let tickets: Vec<(usize, Ticket)> = work
            .iter()
            .zip(&inputs)
            .enumerate()
            .map(|(i, (key, ins))| {
                let tenant = if round % 2 == 0 { "even" } else { "odd" };
                (i, server.submit(request_for(tenant, key, ins)).unwrap())
            })
            .collect();
        for (i, ticket) in tickets {
            // The whole point: this wait RETURNS for every ticket.
            outcomes.push((i, ticket.wait()));
        }
    }

    let submitted = outcomes.len() as u64;
    let mut ok = 0u64;
    for (i, outcome) in outcomes {
        match outcome {
            Ok(reply) => {
                ok += 1;
                assert!(
                    reply.output.allclose(&reference[i], 0.0, 0.0),
                    "{}: successful chaos result diverged from serial reference",
                    work[i].0
                );
            }
            // Budget exhaustion under strided chaos is legitimate — but
            // it must be one of the typed retryable classes, never a
            // hang or an untyped failure.
            Err(e) => assert!(e.is_retryable(), "unexpected error class under chaos: {e}"),
        }
    }

    let st = server.stats();
    assert_eq!(st.submitted, submitted);
    assert_eq!(st.completed, ok, "{st:?}");
    assert_eq!(st.completed + st.errors, submitted, "zero lost tickets: {st:?}");
    assert_eq!(st.in_flight, 0);
    assert_eq!(
        st.restarts,
        plan.fired(site::SERVE_WORKER).panics,
        "every fired worker panic is one supervised restart: {st:?}"
    );
    assert!(
        st.retries >= plan.fired(site::SERVE_RUN).transients.saturating_sub(st.errors),
        "fired transients either retried or consumed the budget: {st:?}"
    );
    // The strided schedule fires on a 48+-tick run (stride 7 at
    // serve.run, 13 at serve.worker): the chaos actually happened.
    assert!(plan.fired(site::SERVE_RUN).transients > 0, "no transients fired");
    assert!(plan.fired(site::SERVE_WORKER).panics > 0, "no worker panics fired");
}

/// Dropping a server with queued work: shutdown drains — every accepted
/// ticket resolves even while the fault plan is stalling workers.
#[test]
fn shutdown_under_injected_latency_drains_all_tickets() {
    let key = ("ij,jk->ik", vec![vec![8, 6], vec![6, 4]]);
    let ins = inputs_for(&key.1, 55);
    let plan = FaultPlan::new().latency_every(
        site::SERVE_WORKER,
        Duration::from_millis(1),
        1,
        0, // every single iteration is slowed
    );
    let session = Session::builder().ranks(2).build().unwrap();
    let server = Server::builder(session).workers(1).fault_plan(plan).build();
    let tickets: Vec<Ticket> =
        (0..8).map(|_| server.submit(request_for("t", &key, &ins)).unwrap()).collect();
    server.shutdown();
    assert!(matches!(
        server.submit(request_for("t", &key, &ins)),
        Err(Error::ServerShutdown)
    ));
    drop(server);
    for t in tickets {
        t.wait().expect("accepted work must drain through a slowed shutdown");
    }
}
