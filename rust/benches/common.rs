//! Shared mini-harness for the paper-reproduction benches (criterion is
//! unavailable in the offline vendored registry; this provides the same
//! essentials: warmup, repetitions, median + spread).

use std::time::Instant;

#[allow(dead_code)]
/// Run `f` `reps` times after one warmup; returns (median, min, max) in
/// seconds.
pub fn time_median<F: FnMut()>(reps: usize, mut f: F) -> (f64, f64, f64) {
    f(); // warmup
    let mut samples: Vec<f64> = (0..reps)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect();
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let med = samples[samples.len() / 2];
    (med, samples[0], *samples.last().unwrap())
}

#[allow(dead_code)]
/// Environment-variable override with default (bench knobs without CLI
/// plumbing: `DEINSUM_BENCH_NODES=512 cargo bench`).
pub fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

#[allow(dead_code)]
/// Pretty time with units.
pub fn fmt_s(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else {
        format!("{:.3}us", s * 1e6)
    }
}
