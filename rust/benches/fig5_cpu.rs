//! Fig. 5 reproduction: CPU weak-scaling series, Deinsum (compute + comm
//! split) vs the CTF-like baseline, for all ten Table IV benchmarks.
//!
//! Knobs (env): DEINSUM_BENCH_NODES (default 64, paper: 512),
//! DEINSUM_BENCH_SIZE_FACTOR (default 16; 1 = paper sizes),
//! DEINSUM_BENCH_REPS (default 3).
//!
//! The absolute numbers are this testbed's, not Piz Daint's; the *shape*
//! — who wins, roughly by how much, and where comm fractions step up —
//! is the reproduction target (EXPERIMENTS.md).

#[path = "common.rs"]
mod common;

use deinsum::bench_support::{geomean, run_point, suite, BenchPoint};
use deinsum::{KernelConfig, Session};

fn main() {
    let max_nodes = common::env_usize("DEINSUM_BENCH_NODES", 64);
    let sf = common::env_usize("DEINSUM_BENCH_SIZE_FACTOR", 16);
    let reps = common::env_usize("DEINSUM_BENCH_REPS", 2);
    // Local-kernel engine config from the environment (RAYON_NUM_THREADS /
    // DEINSUM_NUM_THREADS, DEINSUM_MC/KC/NC); the same KernelConfig the
    // session's engine dispatches with, so the blue compute bars
    // reflect the packed multithreaded kernels.
    let kcfg = KernelConfig::from_env();
    let session = Session::builder()
        .kernel_config(kcfg)
        .plan_cache_capacity(256)
        .build()
        .expect("native session");

    println!("# Fig. 5 (CPU weak scaling) — size-factor {sf}, reps {reps}, up to {max_nodes} nodes");
    println!("# local kernels: {kcfg:?}");
    println!(
        "{:<14} {:>5} {:>12} {:>12} {:>12} {:>12} {:>9}",
        "benchmark", "P", "dein comp", "dein comm", "dein total", "ctf-like", "speedup"
    );

    let mut all: Vec<BenchPoint> = Vec::new();
    for def in suite(sf) {
        let mut p = 1usize;
        while p <= max_nodes {
            // One unmeasured warmup (first-touch/page-fault effects hit
            // whichever scheduler runs first), then best-of-reps on each
            // side independently.
            let _ = run_point(&def, p, &session).expect("warmup");
            let mut pts: Vec<BenchPoint> = (0..reps)
                .map(|_| run_point(&def, p, &session).expect("bench point").0)
                .collect();
            pts.sort_by(|a, b| {
                a.deinsum.total().partial_cmp(&b.deinsum.total()).unwrap()
            });
            let mut pt = pts[0].clone();
            let best_base = pts
                .iter()
                .map(|q| q.baseline.total())
                .fold(f64::INFINITY, f64::min);
            pt.baseline.compute = best_base - pt.baseline.comm;
            pt.speedup = best_base / pt.deinsum.total().max(1e-12);
            println!(
                "{:<14} {:>5} {:>12} {:>12} {:>12} {:>12} {:>8.2}x",
                pt.name,
                pt.p,
                common::fmt_s(pt.deinsum.compute),
                common::fmt_s(pt.deinsum.comm),
                common::fmt_s(pt.deinsum.total()),
                common::fmt_s(pt.baseline.total()),
                pt.speedup
            );
            all.push(pt);
            p *= 2;
        }
        println!();
    }

    // §VI-B headline block.
    println!("# headline");
    for def in suite(sf) {
        let at_max: Vec<&BenchPoint> =
            all.iter().filter(|pt| pt.name == def.name).collect();
        if let Some(pt) = at_max.last() {
            println!(
                "{:<14} speedup at P={:<4}: {:>6.2}x   comm bytes dein/ctf: {}/{}",
                pt.name, pt.p, pt.speedup, pt.deinsum_comm_bytes, pt.baseline_comm_bytes
            );
        }
    }
    println!("geomean speedup over all points: {:.2}x  (paper: 4.18x)", geomean(&all));
}
