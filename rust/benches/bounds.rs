//! §IV-E reproduction: the MTTKRP I/O lower-bound table.
//!
//! For a sweep of fast-memory sizes S, prints the numerically-derived
//! computational intensity / X₀ / optimal tiles next to the paper's
//! closed forms (`ρ = S^{2/3}/3`, `X₀ = 5S/2`, `I=J=K=S^{1/3}`,
//! `L=S^{2/3}/2`), the classical GEMM bound (`√S/2`, §IV-A), the 6.24×
//! improvement over Ballard et al. [20], and the fused-vs-two-step Q
//! separation whose growth is the paper's `S^{1/6}` claim.

#[path = "common.rs"]
mod common;

use std::collections::BTreeMap;

use deinsum::soap::bound::{AccessSet, Statement};
use deinsum::soap::{
    gemm_rho_closed_form, mttkrp_improvement_factor, mttkrp_rho_closed_form,
};

const BIG: f64 = 1e15;

/// Unfused KRP statement (materializes jka).
fn krp_statement() -> Statement {
    let mut e = BTreeMap::new();
    for c in ['j', 'k', 'a'] {
        e.insert(c, BIG);
    }
    Statement::new(
        e,
        vec![
            AccessSet { name: "A".into(), indices: vec!['j', 'a'] },
            AccessSet { name: "B".into(), indices: vec!['k', 'a'] },
            AccessSet { name: "K".into(), indices: vec!['j', 'k', 'a'] },
        ],
    )
    .unwrap()
}

/// Unfused TDOT statement (consumes the materialized jka).
fn tdot_statement() -> Statement {
    let mut e = BTreeMap::new();
    for c in ['i', 'j', 'k', 'a'] {
        e.insert(c, BIG);
    }
    Statement::new(
        e,
        vec![
            AccessSet { name: "X".into(), indices: vec!['i', 'j', 'k'] },
            AccessSet { name: "K".into(), indices: vec!['j', 'k', 'a'] },
            AccessSet { name: "u".into(), indices: vec!['i', 'a'] },
        ],
    )
    .unwrap()
}

fn main() {
    println!("# Sec. IV-E: tight MTTKRP I/O lower bound, numeric vs closed form");
    println!(
        "{:>12} {:>12} {:>12} {:>8} {:>12} {:>12} {:>10} {:>10}",
        "S", "rho(num)", "rho(paper)", "err%", "X0(num)", "X0=5S/2", "tile I", "S^(1/3)"
    );
    for exp in [10u32, 12, 14, 16, 18, 20, 22, 24] {
        let s = (1u64 << exp) as f64;
        let b = Statement::mttkrp3(BIG, BIG, BIG, BIG).io_bound(s);
        let want = mttkrp_rho_closed_form(s);
        println!(
            "{:>12.3e} {:>12.4e} {:>12.4e} {:>8.3} {:>12.4e} {:>12.4e} {:>10.1} {:>10.1}",
            s,
            b.rho,
            want,
            100.0 * (b.rho - want).abs() / want,
            b.x0,
            2.5 * s,
            b.tiles[&'i'],
            s.powf(1.0 / 3.0),
        );
    }

    println!("\n# GEMM bound (classical anchor, Sec. IV-A)");
    println!("{:>12} {:>12} {:>12} {:>8}", "S", "rho(num)", "sqrt(S)/2", "err%");
    for exp in [12u32, 16, 20, 24] {
        let s = (1u64 << exp) as f64;
        let b = Statement::gemm(BIG, BIG, BIG).io_bound(s);
        let want = gemm_rho_closed_form(s);
        println!(
            "{:>12.3e} {:>12.4e} {:>12.4e} {:>8.3}",
            s,
            b.rho,
            want,
            100.0 * (b.rho - want).abs() / want
        );
    }

    println!(
        "\n# improvement over Ballard et al. [20]: 3^(5/3) = {:.4} (paper: ~6.24)",
        mttkrp_improvement_factor()
    );

    println!("\n# fused vs two-step MTTKRP: the asymptotic S^(1/6) separation");
    println!("# (rho_fused / rho_tdot -> (2/3) S^(1/6): the TDOT stage of the");
    println!("# two-step pipeline has GEMM-like intensity O(sqrt(S)), the fused");
    println!("# kernel reaches S^(2/3)/3 — Sec. IV-E)");
    println!(
        "{:>12} {:>12} {:>12} {:>10} {:>14}",
        "S", "rho fused", "rho tdot", "ratio", "(2/3)S^(1/6)"
    );
    for exp in [14u32, 18, 22, 26, 30] {
        let s = (1u64 << exp) as f64;
        let fused = Statement::mttkrp3(BIG, BIG, BIG, BIG).io_bound(s);
        let tdot_b = tdot_statement().io_bound(s);
        // KRP sanity: materialization keeps rho O(1), so its Q is a pure
        // JKA overhead the fused schedule never pays.
        let krp_b = krp_statement().io_bound(s);
        assert!(krp_b.rho < 3.0);
        let ratio = fused.rho / tdot_b.rho;
        println!(
            "{:>12.3e} {:>12.4e} {:>12.4e} {:>10.3} {:>14.3}",
            s,
            fused.rho,
            tdot_b.rho,
            ratio,
            (2.0 / 3.0) * s.powf(1.0 / 6.0)
        );
        assert!(ratio > 1.0, "fused intensity must exceed two-step's");
    }

    // Timing the bound machinery itself (it sits on the planning path).
    let (med, _, _) = common::time_median(5, || {
        let _ = Statement::mttkrp3(BIG, BIG, BIG, BIG).io_bound(1e8);
    });
    println!("\n# io_bound() solve time: {} per statement", common::fmt_s(med));
}
