//! Fig. 6 reproduction: GPU runs under the accelerator model — Deinsum
//! GPU-resident vs Deinsum accelerator-mode (H2D/D2H copies charged) vs
//! the CTF-like baseline (accelerator-mode only, like CTF).
//!
//! Same schedules as Fig. 5; only the time model changes (DESIGN.md
//! §Substitutions): device compute = measured CPU kernel time / speedup,
//! copies at PCIe bandwidth.  The reproduction target is the *structure*:
//! copy overhead dominates small-P points and GPU-resident execution
//! strictly beats accelerator mode.

#[path = "common.rs"]
mod common;

use deinsum::bench_support::{run_point, suite};
use deinsum::sim::AccelModel;
use deinsum::Session;

fn main() {
    let max_nodes = common::env_usize("DEINSUM_BENCH_NODES", 32);
    let sf = common::env_usize("DEINSUM_BENCH_SIZE_FACTOR", 16);
    let session =
        Session::builder().plan_cache_capacity(256).build().expect("native session");
    let accel = AccelModel::p100();

    println!("# Fig. 6 (GPU model: P100-class, {:.0}x kernels, {:.0} GB/s PCIe)",
        accel.speedup, accel.pcie_bw / 1e9);
    println!(
        "{:<14} {:>5} {:>14} {:>14} {:>14} {:>9}",
        "benchmark", "P", "dein resident", "dein accel", "ctf-like accel", "speedup"
    );

    for def in suite(sf) {
        let mut p = 1usize;
        while p <= max_nodes {
            let (_, drep, brep) = run_point(&def, p, &session).expect("bench point");
            let resident = drep.gpu_time(&accel, true);
            let offload = drep.gpu_time(&accel, false);
            let base = brep.gpu_time(&accel, false);
            println!(
                "{:<14} {:>5} {:>14} {:>14} {:>14} {:>8.2}x",
                def.name,
                p,
                common::fmt_s(resident.total()),
                common::fmt_s(offload.total()),
                common::fmt_s(base.total()),
                base.total() / offload.total().max(1e-12)
            );
            assert!(
                resident.total() <= offload.total() + 1e-12,
                "GPU-resident must not exceed accelerator mode"
            );
            p *= 2;
        }
        println!();
    }
}
