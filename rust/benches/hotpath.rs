//! Hot-path microbenches for the §Perf pass (EXPERIMENTS.md): the pieces
//! on the coordinator's critical path, timed in isolation so regressions
//! are attributable.
//!
//! - native GEMM microkernel (local compute floor)
//! - fused MTTKRP kernel vs two-step (local)
//! - HPTT-lite transposition
//! - redistribution *planning* (must be O(messages), never O(elements))
//! - redistribution *execution* (memcpy-bound)
//! - end-to-end plan construction (SOAP solve + grid search)

#[path = "common.rs"]
mod common;

use deinsum::dist::TensorDist;
use deinsum::einsum::EinsumSpec;
use deinsum::grid::ProcessGrid;
use deinsum::planner::{plan, PlannerConfig};
use deinsum::redist;
use deinsum::tensor::{contract, Tensor};

fn main() {
    let reps = common::env_usize("DEINSUM_BENCH_REPS", 5);

    // --- GEMM microkernel ---------------------------------------------------
    for n in [128usize, 256, 512] {
        let a = Tensor::random(&[n, n], 1);
        let b = Tensor::random(&[n, n], 2);
        let (med, _, _) = common::time_median(reps, || {
            let _ = contract::gemm(&a, &b).unwrap();
        });
        let gflops = 2.0 * (n as f64).powi(3) / med / 1e9;
        println!("gemm {n}x{n}x{n}: {} ({gflops:.2} GFLOP/s)", common::fmt_s(med));
    }

    // --- fused MTTKRP vs two-step (local kernels) ----------------------------
    for n in [64usize, 128] {
        let x = Tensor::random(&[n, n, n], 3);
        let f1 = Tensor::random(&[n, 24], 4);
        let f2 = Tensor::random(&[n, 24], 5);
        let slots = [&x, &f1, &f2];
        let (fused, _, _) = common::time_median(reps, || {
            let _ = contract::mttkrp(&x, &slots, 0).unwrap();
        });
        let (two, _, _) = common::time_median(reps, || {
            let _ = contract::mttkrp_two_step(&x, &slots, 0).unwrap();
        });
        let flops = 2.0 * (n as f64).powi(3) * 24.0;
        println!(
            "mttkrp {n}^3 r24: fused {} ({:.2} GFLOP/s) vs two-step {} ({:.2}x)",
            common::fmt_s(fused),
            flops / fused / 1e9,
            common::fmt_s(two),
            two / fused
        );
    }

    // --- transposition --------------------------------------------------------
    for dims in [[256usize, 256, 16], [64, 64, 64]] {
        let t = Tensor::random(&dims, 6);
        let (med, _, _) = common::time_median(reps, || {
            let _ = t.permute(&[2, 1, 0]);
        });
        let gbs = (t.len() * 8) as f64 / med / 1e9; // read + write
        println!(
            "permute {:?} [2,1,0]: {} ({gbs:.2} GB/s)",
            dims,
            common::fmt_s(med)
        );
    }

    // --- redistribution planning: must not scale with element count ----------
    for n in [1usize << 12, 1 << 16, 1 << 20] {
        let ga = ProcessGrid::new(&[8, 8]).unwrap();
        let gb = ProcessGrid::new(&[16, 4]).unwrap();
        let src = TensorDist::new(&[n, 64], &ga, &[0, 1]).unwrap();
        let dst = TensorDist::new(&[n, 64], &gb, &[0, 1]).unwrap();
        let (med, _, _) = common::time_median(reps, || {
            let _ = redist::plan(&src, &dst).unwrap();
        });
        let msgs = redist::plan(&src, &dst).unwrap().messages.len();
        println!(
            "redist plan rows={n} (64 ranks, {msgs} msgs): {}",
            common::fmt_s(med)
        );
    }

    // --- redistribution execution (data movement) -----------------------------
    {
        let n = 1usize << 20;
        let ga = ProcessGrid::new(&[8]).unwrap();
        let gb = ProcessGrid::new(&[4]).unwrap();
        let src = TensorDist::new(&[n], &ga, &[0]).unwrap();
        let dst = TensorDist::new(&[n], &gb, &[0]).unwrap();
        let rp = redist::plan(&src, &dst).unwrap();
        let global = Tensor::random(&[n], 7);
        let bufs: Vec<Tensor> = (0..8)
            .map(|r| {
                let (off, _) = src.block_for_rank(r);
                global.block(&off, &src.local_dims())
            })
            .collect();
        let (med, _, _) = common::time_median(reps, || {
            let _ = redist::execute(&rp, &src, &dst, &bufs).unwrap();
        });
        let gbs = (n * 4) as f64 / med / 1e9;
        println!("redist execute {n} f32 over 8->4 ranks: {} ({gbs:.2} GB/s)", common::fmt_s(med));
    }

    // --- plan construction (SOAP + grids + moves) ------------------------------
    {
        let n = 1usize << 12;
        let spec = EinsumSpec::parse(
            "ijk,ja,ka,al->il",
            &[vec![n, n, n], vec![n, 24], vec![n, 24], vec![24, n]],
        )
        .unwrap();
        let (med, _, _) = common::time_median(reps, || {
            let _ = plan(&spec, 64, &PlannerConfig::default()).unwrap();
        });
        println!("plan(worked example, P=64): {}", common::fmt_s(med));
    }
}
