//! Hot-path microbenches for the §Perf pass (EXPERIMENTS.md): the pieces
//! on the coordinator's critical path, timed in isolation so regressions
//! are attributable.
//!
//! - packed GEMM (multi + single thread) vs the seed scalar kernel
//! - fused MTTKRP (multi + single thread, SOAP-derived tiles) vs two-step
//! - HPTT-lite transposition, serial vs threaded
//! - parallel-region dispatch: persistent pool vs per-step thread spawn
//! - redistribution *planning* (must be O(messages), never O(elements))
//! - redistribution *execution* (memcpy-bound, recycled destinations)
//! - end-to-end plan construction (SOAP solve + grid search)
//! - program compile through the `Session` front door: plan-cache hit
//!   vs cold plan (`program_compile_cached` / `program_compile_cold`)
//! - coordinator steady state: a warm `Program` re-run (persistent
//!   machine + warm pools) vs the cold first-query path (fresh session,
//!   cache-miss compile, spawn-dispatch baseline), on a multi-step plan
//!   (`DEINSUM_BENCH_TINY=1` shrinks it for CI smoke runs)
//! - execution backends: the same warm re-run on the message-passing
//!   backend (`machine_backend_mp`, speedup = sim/mp) and a
//!   redistribution-dominated chain over real channels
//!   (`redistribute_mp`)
//! - differential fuzz campaign throughput (`fuzz_campaign`): cases/sec
//!   of generate + oracle + compile/run at ranks {1,4,8} over the
//!   fixed-seed tiny corpus (src/fuzz)
//!
//! Besides the human-readable table, results land in
//! `BENCH_hotpath.json` (override with `DEINSUM_BENCH_JSON`) as
//! `{"config": ..., "results": [{kernel, shape, median_seconds, gflops?,
//! speedup?}, ...]}` so future PRs have a perf trajectory to diff.  The
//! `coordinator_steady_state` entry also carries `allocs_per_run`: the
//! total tensor/scratch allocations one warm `Program::run_into` performs
//! (engine pool + store destinations + compute outputs + local scratch +
//! gather) — 0 is the recycled-everything invariant the tests pin.

#[path = "common.rs"]
mod common;

use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};

use deinsum::dist::TensorDist;
use deinsum::einsum::EinsumSpec;
use deinsum::grid::ProcessGrid;
use deinsum::planner::{plan, PlannerConfig};
use deinsum::redist;
use deinsum::runtime::{pool, KernelEngine};
use deinsum::tensor::kernel::{self, KernelConfig, ScratchPool};
use deinsum::tensor::{contract, transpose, Tensor};
use deinsum::Session;

/// The single JSON-line formatter every bench entry goes through (so the
/// schema lives in one place).
fn record_full(
    out: &mut Vec<String>,
    kernel: &str,
    shape: &str,
    median_s: f64,
    gflops: Option<f64>,
    speedup: Option<f64>,
    allocs_per_run: Option<u64>,
) {
    let mut s = format!(
        "    {{\"kernel\": \"{kernel}\", \"shape\": \"{shape}\", \"median_seconds\": {median_s:.9}"
    );
    if let Some(g) = gflops {
        let _ = write!(s, ", \"gflops\": {g:.3}");
    }
    if let Some(x) = speedup {
        let _ = write!(s, ", \"speedup\": {x:.3}");
    }
    if let Some(a) = allocs_per_run {
        let _ = write!(s, ", \"allocs_per_run\": {a}");
    }
    s.push('}');
    out.push(s);
}

fn record(
    out: &mut Vec<String>,
    kernel: &str,
    shape: &str,
    median_s: f64,
    gflops: Option<f64>,
    speedup: Option<f64>,
) {
    record_full(out, kernel, shape, median_s, gflops, speedup, None);
}

fn main() {
    let reps = common::env_usize("DEINSUM_BENCH_REPS", 5);
    // Smoke mode: every section shrinks so CI can exercise the full
    // bench surface (including coordinator_steady_state) in seconds.
    let tiny = std::env::var("DEINSUM_BENCH_TINY").is_ok();
    let cfg = KernelConfig::from_env();
    let serial = cfg.serial();
    let scratch = ScratchPool::new();
    let mut records: Vec<String> = Vec::new();
    println!("# kernel config: {cfg:?} tiny={tiny}");

    // --- GEMM: seed scalar kernel vs packed engine ---------------------------
    let gemm_sizes: &[usize] = if tiny { &[96] } else { &[128, 256, 512] };
    for &n in gemm_sizes {
        let a = Tensor::random(&[n, n], 1);
        let b = Tensor::random(&[n, n], 2);
        let flops = 2.0 * (n as f64).powi(3);
        let shape = format!("{n}x{n}x{n}");
        let mut c = vec![0.0f32; n * n];

        let (scalar, _, _) = common::time_median(reps, || {
            c.fill(0.0);
            contract::gemm_scalar_into(a.data(), b.data(), &mut c, n, n, n);
        });
        let (packed1, _, _) = common::time_median(reps, || {
            c.fill(0.0);
            kernel::gemm_into_with(&serial, &scratch, a.data(), b.data(), &mut c, n, n, n);
        });
        let (packed, _, _) = common::time_median(reps, || {
            c.fill(0.0);
            kernel::gemm_into_with(&cfg, &scratch, a.data(), b.data(), &mut c, n, n, n);
        });
        println!(
            "gemm {shape}: scalar {} ({:.2} GF/s) | packed-1t {} ({:.2} GF/s, {:.2}x) | packed-{}t {} ({:.2} GF/s, {:.2}x)",
            common::fmt_s(scalar),
            flops / scalar / 1e9,
            common::fmt_s(packed1),
            flops / packed1 / 1e9,
            scalar / packed1,
            cfg.threads,
            common::fmt_s(packed),
            flops / packed / 1e9,
            scalar / packed
        );
        record(&mut records, "gemm_scalar", &shape, scalar, Some(flops / scalar / 1e9), None);
        record(
            &mut records,
            "gemm_packed_1t",
            &shape,
            packed1,
            Some(flops / packed1 / 1e9),
            Some(scalar / packed1),
        );
        record(
            &mut records,
            "gemm_packed",
            &shape,
            packed,
            Some(flops / packed / 1e9),
            Some(scalar / packed),
        );
    }

    // --- fused MTTKRP vs two-step (local kernels) ----------------------------
    let mttkrp_sizes: &[usize] = if tiny { &[48] } else { &[64, 128] };
    for &n in mttkrp_sizes {
        let r = 24usize;
        let x = Tensor::random(&[n, n, n], 3);
        let f1 = Tensor::random(&[n, r], 4);
        let f2 = Tensor::random(&[n, r], 5);
        let slots = [&x, &f1, &f2];
        let flops = 2.0 * (n as f64).powi(3) * r as f64;
        let shape = format!("{n}^3 r{r}");

        // SOAP-derived blocks through the coordinator's own feed
        // (KernelEngine::configure_for_term) — the §IV story end to end,
        // with no bench-side reimplementation of the derivation.
        let spec = EinsumSpec::parse(
            "ijk,ja,ka->ia",
            &[vec![n, n, n], vec![n, r], vec![n, r]],
        )
        .unwrap();
        let feed_engine = KernelEngine::native_with(cfg);
        let soap_cfg = plan(&spec, 1, &PlannerConfig::default())
            .map(|p| {
                feed_engine.configure_for_term(&p.terms[0]);
                let derived = feed_engine.config();
                // The override is thread-local (and engine-tagged) since
                // 0.6.0; clear it rather than leave a stale entry behind.
                feed_engine.reset_config();
                derived
            })
            .unwrap_or(cfg);

        let (two, _, _) = common::time_median(reps, || {
            let _ = contract::mttkrp_two_step(&x, &slots, 0).unwrap();
        });
        let (fused1, _, _) = common::time_median(reps, || {
            let _ = contract::mttkrp_with(&serial, &scratch, &x, &slots, 0).unwrap();
        });
        let (fused, _, _) = common::time_median(reps, || {
            let _ = contract::mttkrp_with(&cfg, &scratch, &x, &slots, 0).unwrap();
        });
        let (fused_soap, _, _) = common::time_median(reps, || {
            let _ = contract::mttkrp_with(&soap_cfg, &scratch, &x, &slots, 0).unwrap();
        });
        println!(
            "mttkrp {shape}: two-step {} | fused-1t {} ({:.2}x) | fused-{}t {} ({:.2} GF/s, {:.2}x) | soap-tiles {}",
            common::fmt_s(two),
            common::fmt_s(fused1),
            two / fused1,
            cfg.threads,
            common::fmt_s(fused),
            flops / fused / 1e9,
            two / fused,
            common::fmt_s(fused_soap)
        );
        record(&mut records, "mttkrp_two_step", &shape, two, Some(flops / two / 1e9), None);
        record(
            &mut records,
            "mttkrp_fused_1t",
            &shape,
            fused1,
            Some(flops / fused1 / 1e9),
            Some(two / fused1),
        );
        record(
            &mut records,
            "mttkrp_fused",
            &shape,
            fused,
            Some(flops / fused / 1e9),
            Some(two / fused),
        );
        record(
            &mut records,
            "mttkrp_fused_soap_tiles",
            &shape,
            fused_soap,
            Some(flops / fused_soap / 1e9),
            Some(two / fused_soap),
        );
    }

    // --- transposition: serial vs threaded -----------------------------------
    let permute_dims: &[[usize; 3]] = if tiny {
        &[[64, 64, 64]]
    } else {
        &[[256, 256, 16], [64, 64, 64], [512, 384, 4]]
    };
    for &dims in permute_dims {
        let t = Tensor::random(&dims, 6);
        let bytes = (t.len() * 8) as f64; // read + write
        let shape = format!("{dims:?} perm [2,1,0]");
        let (ser, _, _) = common::time_median(reps, || {
            let _ = transpose::permute_with(&serial, &t, &[2, 1, 0]);
        });
        let (par, _, _) = common::time_median(reps, || {
            let _ = transpose::permute_with(&cfg, &t, &[2, 1, 0]);
        });
        println!(
            "permute {shape}: serial {} ({:.2} GB/s) | {}t {} ({:.2} GB/s, {:.2}x)",
            common::fmt_s(ser),
            bytes / ser / 1e9,
            cfg.threads,
            common::fmt_s(par),
            bytes / par / 1e9,
            ser / par
        );
        record(&mut records, "permute_serial", &shape, ser, None, None);
        record(&mut records, "permute", &shape, par, None, Some(ser / par));
    }

    // --- redistribution planning: must not scale with element count ----------
    let plan_rows: &[usize] = if tiny { &[1 << 12] } else { &[1 << 12, 1 << 16, 1 << 20] };
    for &n in plan_rows {
        let ga = ProcessGrid::new(&[8, 8]).unwrap();
        let gb = ProcessGrid::new(&[16, 4]).unwrap();
        let src = TensorDist::new(&[n, 64], &ga, &[0, 1]).unwrap();
        let dst = TensorDist::new(&[n, 64], &gb, &[0, 1]).unwrap();
        let (med, _, _) = common::time_median(reps, || {
            let _ = redist::plan(&src, &dst).unwrap();
        });
        let msgs = redist::plan(&src, &dst).unwrap().messages.len();
        println!("redist plan rows={n} (64 ranks, {msgs} msgs): {}", common::fmt_s(med));
        record(&mut records, "redist_plan", &format!("rows={n} p=64"), med, None, None);
    }

    // --- redistribution execution (data movement, recycled dests) -------------
    {
        let n = if tiny { 1usize << 14 } else { 1usize << 20 };
        let ga = ProcessGrid::new(&[8]).unwrap();
        let gb = ProcessGrid::new(&[4]).unwrap();
        let src = TensorDist::new(&[n], &ga, &[0]).unwrap();
        let dst = TensorDist::new(&[n], &gb, &[0]).unwrap();
        let rp = redist::plan(&src, &dst).unwrap();
        let global = Tensor::random(&[n], 7);
        let bufs: Vec<Tensor> = (0..8)
            .map(|r| {
                let (off, _) = src.block_for_rank(r);
                global.block(&off, &src.local_dims())
            })
            .collect();
        // Steady-state data path: execute_into over recycled destination
        // buffers (what Machine::redistribute does across runs) — pure
        // box movement, no allocation in the timed region.
        let mut dst_bufs: Vec<Tensor> =
            (0..gb.size()).map(|_| Tensor::zeros(&dst.local_dims())).collect();
        let (med, _, _) = common::time_median(reps, || {
            redist::execute_into(&rp, &bufs, &mut dst_bufs);
        });
        let gbs = (n * 4) as f64 / med / 1e9;
        println!(
            "redist execute {n} f32 over 8->4 ranks: {} ({gbs:.2} GB/s)",
            common::fmt_s(med)
        );
        record(&mut records, "redist_execute", &format!("{n} f32 8->4"), med, None, None);
    }

    // --- parallel-region dispatch: persistent pool vs per-step spawn ----------
    {
        let threads = cfg.threads.max(2).min(8);
        let regions = 64usize;
        let sink = AtomicU64::new(0);
        let tiny_region = |t: usize| {
            sink.fetch_add(t as u64 + 1, Ordering::Relaxed);
        };
        // Warm the pool so the measurement sees steady state, not spawn.
        pool::global().run(threads, 16, &tiny_region);
        let (pooled, _, _) = common::time_median(reps, || {
            for _ in 0..regions {
                pool::global().run(threads, 16, &tiny_region);
            }
        });
        let (spawned, _, _) = common::time_median(reps, || {
            for _ in 0..regions {
                pool::run_scoped(threads, 16, &tiny_region);
            }
        });
        println!(
            "dispatch {regions} regions x 16 tasks ({threads}t): pool {} | spawn {} ({:.2}x)",
            common::fmt_s(pooled),
            common::fmt_s(spawned),
            spawned / pooled
        );
        let shape = format!("{regions}x16 tasks {threads}t");
        record(&mut records, "spawn_dispatch", &shape, spawned, None, None);
        record(&mut records, "pool_dispatch", &shape, pooled, None, Some(spawned / pooled));
    }

    // --- plan construction (SOAP + grids + moves) ------------------------------
    {
        let n = 1usize << 12;
        let spec = EinsumSpec::parse(
            "ijk,ja,ka,al->il",
            &[vec![n, n, n], vec![n, 24], vec![n, 24], vec![24, n]],
        )
        .unwrap();
        let (med, _, _) = common::time_median(reps, || {
            let _ = plan(&spec, 64, &PlannerConfig::default()).unwrap();
        });
        println!("plan(worked example, P=64): {}", common::fmt_s(med));
        record(&mut records, "plan_worked_example", "P=64", med, None, None);
    }

    // --- program compile: plan-cache hit vs cold plan --------------------------
    {
        let n = 1usize << 12;
        let expr = "ijk,ja,ka,al->il";
        let shapes = vec![vec![n, n, n], vec![n, 24], vec![n, 24], vec![24, n]];
        let (cold, _, _) = common::time_median(reps, || {
            // Fresh session per iteration: every compile misses the plan
            // cache and pays the full SOAP solve + grid search.
            let session = Session::builder().ranks(64).build().unwrap();
            let _ = session.compile(expr, &shapes).unwrap();
        });
        let session = Session::builder().ranks(64).build().unwrap();
        let _ = session.compile(expr, &shapes).unwrap(); // prime the cache
        let (cached, _, _) = common::time_median(reps, || {
            let _ = session.compile(expr, &shapes).unwrap();
        });
        assert!(session.cache_stats().hits >= 1, "cached compiles must hit");
        println!(
            "program compile (worked example, P=64): cold {} | cache-hit {} ({:.2}x)",
            common::fmt_s(cold),
            common::fmt_s(cached),
            cold / cached
        );
        record(&mut records, "program_compile_cold", "P=64", cold, None, None);
        record(
            &mut records,
            "program_compile_cached",
            "P=64",
            cached,
            None,
            Some(cold / cached),
        );
    }

    // --- coordinator steady state: persistent runtime vs per-step spawn -------
    //
    // A multi-step plan (forced two-term split => staging + local compute
    // + redistribution + allreduce per run).  Baseline reconstructs the
    // PR 1 runtime: spawn-per-macro-step dispatch and a fresh session +
    // program per run (cold plan cache, cold scratch pool, cold machine
    // store — first-query latency through the front door).  Steady state
    // is the persistent runtime: one warm `Program` re-run.
    {
        let n = if tiny { 12 } else { 48 };
        let r = 24usize;
        let expr = "ijk,ja,ka,al->il";
        let shapes = vec![vec![n, n, n], vec![n, r], vec![n, r], vec![r, n]];
        let pcfg = PlannerConfig { s_elements: 64.0, ..Default::default() };
        let inputs: Vec<Tensor> = vec![
            Tensor::random(&[n, n, n], 21),
            Tensor::random(&[n, r], 22),
            Tensor::random(&[n, r], 23),
            Tensor::random(&[r, n], 24),
        ];
        let mk_session = || {
            Session::builder().ranks(8).planner(pcfg).kernel_config(cfg).build().unwrap()
        };
        // A Program outlives its Session (it shares the engine by Rc).
        let probe = mk_session().compile(expr, &shapes).unwrap();
        let shape = format!("{n}^3 r{r} P=8 terms={}", probe.plan().terms.len());
        drop(probe);

        pool::set_spawn_baseline(true);
        let (cold, _, _) = common::time_median(reps, || {
            let session = mk_session();
            let mut prog = session.compile(expr, &shapes).unwrap();
            let _ = prog.run(&inputs).unwrap();
        });
        pool::set_spawn_baseline(false);

        let session = mk_session();
        let mut prog = session.compile(expr, &shapes).unwrap();
        for _ in 0..2 {
            let _ = prog.run(&inputs).unwrap();
        }
        let warm = prog.stats();
        let (steady, _, _) = common::time_median(reps, || {
            let _ = prog.run(&inputs).unwrap();
        });
        // Store-level recycling is a deterministic invariant (also pinned
        // by tests); engine scratch can still grow to its high-water mark
        // during timed runs when worker overlap first peaks.
        let timed = prog.stats();
        assert_eq!(
            timed.store.dest_allocs + timed.store.out_allocs,
            warm.store.dest_allocs + warm.store.out_allocs,
            "steady-state program re-allocated store buffers"
        );
        // One precisely-bracketed run for the allocations-per-run figure,
        // through the fully-recycled output path (`run_into`).
        let mut out = Tensor::zeros(&prog.output_dims());
        prog.run_into(&inputs, &mut out).unwrap(); // warm the gather path
        let before_run = prog.stats().allocs();
        prog.run_into(&inputs, &mut out).unwrap();
        let allocs_per_run = prog.stats().allocs() - before_run;
        println!(
            "coordinator {shape}: cold+spawn+plan {} | steady {} ({:.2}x) | allocs/run {} (timed-window total +{})",
            common::fmt_s(cold),
            common::fmt_s(steady),
            cold / steady,
            allocs_per_run,
            prog.stats().allocs() - warm.allocs()
        );
        record(&mut records, "coordinator_cold_start", &shape, cold, None, None);
        record_full(
            &mut records,
            "coordinator_steady_state",
            &shape,
            steady,
            None,
            Some(cold / steady),
            Some(allocs_per_run),
        );
    }

    // --- message-passing backend: steady state + redistribution ----------------
    //
    // The same warm-Program rerun as coordinator_steady_state, executed
    // on the mp backend (one thread per rank, channel traffic for every
    // redistribution/allreduce) — tracks the channel protocol's overhead
    // over the in-process simulator.  `allocs_per_run` counts per-program
    // tensor allocations (store + local scratch) of one bracketed warm
    // run_into; the session-wide engine pool is excluded because mp rank
    // threads hit it concurrently (its high-water mark is not
    // deterministic there).
    {
        use deinsum::ExecBackend;
        let n = if tiny { 12 } else { 48 };
        let r = 24usize;
        let expr = "ijk,ja,ka,al->il";
        let shapes = vec![vec![n, n, n], vec![n, r], vec![n, r], vec![r, n]];
        let pcfg = PlannerConfig { s_elements: 64.0, ..Default::default() };
        let inputs: Vec<Tensor> = vec![
            Tensor::random(&[n, n, n], 41),
            Tensor::random(&[n, r], 42),
            Tensor::random(&[n, r], 43),
            Tensor::random(&[r, n], 44),
        ];
        let time_backend = |backend: ExecBackend| -> (f64, u64) {
            let session = Session::builder()
                .ranks(8)
                .planner(pcfg)
                .kernel_config(cfg)
                .backend(backend)
                .build()
                .unwrap();
            let mut prog = session.compile(expr, &shapes).unwrap();
            let mut out = Tensor::zeros(&prog.output_dims());
            for _ in 0..2 {
                prog.run_into(&inputs, &mut out).unwrap();
            }
            let (med, _, _) = common::time_median(reps, || {
                prog.run_into(&inputs, &mut out).unwrap();
            });
            // Precisely-bracketed per-run tensor allocations (must be 0).
            let before = prog.stats().tensor_allocs();
            prog.run_into(&inputs, &mut out).unwrap();
            let allocs = prog.stats().tensor_allocs() - before;
            (med, allocs)
        };
        let (sim_med, _) = time_backend(ExecBackend::Sim);
        let (mp_med, mp_allocs) = time_backend(ExecBackend::Mp);
        let shape = format!("{n}^3 r{r} P=8 two-term");
        println!(
            "backend {shape}: sim {} | mp {} ({:.2}x) | mp tensor allocs/run {mp_allocs}",
            common::fmt_s(sim_med),
            common::fmt_s(mp_med),
            sim_med / mp_med,
        );
        record_full(
            &mut records,
            "machine_backend_mp",
            &shape,
            mp_med,
            None,
            Some(sim_med / mp_med),
            Some(mp_allocs),
        );

        // Redistribution-dominated matrix chain on the mp backend: every
        // inter-term move is real rank-to-rank channel traffic.
        let cexpr = "ij,jk,kl->il";
        let m = if tiny { 32 } else { 128 };
        let cshapes = vec![vec![m, m], vec![m, m], vec![m, m]];
        let cinputs: Vec<Tensor> = cshapes
            .iter()
            .enumerate()
            .map(|(i, s)| Tensor::random(s, 51 + i as u64))
            .collect();
        let session = Session::builder()
            .ranks(8)
            .planner(pcfg)
            .kernel_config(cfg)
            .backend(ExecBackend::Mp)
            .build()
            .unwrap();
        let mut prog = session.compile(cexpr, &cshapes).unwrap();
        let moves = prog.plan().moves.len();
        let mut out = Tensor::zeros(&prog.output_dims());
        for _ in 0..2 {
            prog.run_into(&cinputs, &mut out).unwrap();
        }
        let (med, _, _) = common::time_median(reps, || {
            prog.run_into(&cinputs, &mut out).unwrap();
        });
        println!(
            "redistribute mp {cexpr} {m}^2 P=8 ({moves} moves): {} per run",
            common::fmt_s(med)
        );
        record(
            &mut records,
            "redistribute_mp",
            &format!("{m}^2 chain P=8"),
            med,
            None,
            None,
        );

        // Same two probes across the process boundary: 8 spawned
        // `deinsum rank-worker` children per session (`cargo bench`
        // builds the bin target next to the bench executable), every
        // instruction and payload length-prefix-framed over pipes —
        // tracks the wire format's overhead over mp's channels.
        let (proc_med, proc_allocs) = time_backend(ExecBackend::Proc);
        println!(
            "backend {shape}: sim {} | proc {} ({:.2}x) | coordinator tensor allocs/run {proc_allocs}",
            common::fmt_s(sim_med),
            common::fmt_s(proc_med),
            sim_med / proc_med,
        );
        record_full(
            &mut records,
            "machine_backend_proc",
            &shape,
            proc_med,
            None,
            Some(sim_med / proc_med),
            Some(proc_allocs),
        );

        let session = Session::builder()
            .ranks(8)
            .planner(pcfg)
            .kernel_config(cfg)
            .backend(ExecBackend::Proc)
            .build()
            .unwrap();
        let mut prog = session.compile(cexpr, &cshapes).unwrap();
        let mut out = Tensor::zeros(&prog.output_dims());
        for _ in 0..2 {
            prog.run_into(&cinputs, &mut out).unwrap();
        }
        let (med, _, _) = common::time_median(reps, || {
            prog.run_into(&cinputs, &mut out).unwrap();
        });
        println!(
            "redistribute proc {cexpr} {m}^2 P=8 ({moves} moves): {} per run",
            common::fmt_s(med)
        );
        record(
            &mut records,
            "redistribute_proc",
            &format!("{m}^2 chain P=8"),
            med,
            None,
            None,
        );
    }

    // --- serving throughput: 1 worker vs 8 workers -----------------------------
    //
    // Mixed MTTKRP/TTMc/GEMM traffic over 8 distinct program keys (so
    // key-affinity routing can spread across all 8 workers), driven
    // closed-loop: submit a full batch, wait for every ticket, recycle
    // each reply's output tensor as the next round's destination.  The
    // 8w/1w ratio is the serving layer's scaling headline.
    {
        use deinsum::{ServeRequest, Server, Ticket};
        let n = if tiny { 8 } else { 16 };
        let r = 4usize;
        let keys: Vec<(String, Vec<Vec<usize>>)> = vec![
            ("ijk,ja,ka->ia".into(), vec![vec![n, n, n], vec![n, r], vec![n, r]]),
            ("ijk,ia,ka->ja".into(), vec![vec![n, n, n], vec![n, r], vec![n, r]]),
            ("ijk,ia,ja->ka".into(), vec![vec![n, n, n], vec![n, r], vec![n, r]]),
            ("ijk,ja,ka->ai".into(), vec![vec![n, n, n], vec![n, r], vec![n, r]]),
            (
                "ijkl,jb,kc,ld->ibcd".into(),
                vec![vec![n, n, n, n], vec![n, 3], vec![n, 3], vec![n, 3]],
            ),
            ("ij,jk->ik".into(), vec![vec![2 * n, n], vec![n, n]]),
            ("ij,jk->ki".into(), vec![vec![2 * n, n], vec![n, n]]),
            ("ij,jk,kl->il".into(), vec![vec![n, n], vec![n, n], vec![n, n]]),
        ];
        let inputs: Vec<std::sync::Arc<Vec<Tensor>>> = keys
            .iter()
            .enumerate()
            .map(|(i, (_, shapes))| {
                std::sync::Arc::new(
                    shapes
                        .iter()
                        .enumerate()
                        .map(|(j, s)| Tensor::random(s, (31 + 7 * i + j) as u64))
                        .collect::<Vec<_>>(),
                )
            })
            .collect();
        let batch = if tiny { 16usize } else { 64 };
        let shape = format!("{} keys x {batch} reqs n={n}", keys.len());
        let mut medians = Vec::new();
        for &workers in &[1usize, 8] {
            let session =
                Session::builder().ranks(8).kernel_config(cfg).build().unwrap();
            let server = Server::builder(session).workers(workers).build();
            // Per-slot recycled destinations (closed loop: replies hand
            // them back for the next round).
            let mut dests: Vec<Option<Tensor>> = (0..batch)
                .map(|q| {
                    let (expr, shapes) = &keys[q % keys.len()];
                    Some(Tensor::zeros(&Server::output_dims(expr, shapes).unwrap()))
                })
                .collect();
            let drive = |dests: &mut Vec<Option<Tensor>>| {
                let tickets: Vec<Ticket> = (0..batch)
                    .map(|q| {
                        let (expr, shapes) = &keys[q % keys.len()];
                        server
                            .submit(ServeRequest {
                                tenant: format!("bench-{}", q % 2),
                                expr: expr.clone(),
                                shapes: shapes.clone(),
                                inputs: std::sync::Arc::clone(&inputs[q % keys.len()]),
                                dest: dests[q].take().unwrap(),
                            })
                            .unwrap()
                    })
                    .collect();
                for (q, t) in tickets.into_iter().enumerate() {
                    dests[q] = Some(t.wait().unwrap().output);
                }
            };
            drive(&mut dests); // warm every worker's programs
            let (med, _, _) = common::time_median(reps, || drive(&mut dests));
            let rps = batch as f64 / med;
            println!(
                "serve {shape} {workers}w: {} per batch ({rps:.0} req/s, p99 {:.6}s, hit rate {:.2})",
                common::fmt_s(med),
                server.stats().p99_latency_s,
                server.stats().hit_rate(),
            );
            medians.push(med);
            record(
                &mut records,
                &format!("serve_throughput_{workers}w"),
                &shape,
                med,
                None,
                if workers == 8 { Some(medians[0] / med) } else { None },
            );
        }
    }

    // --- serving throughput: fused same-key batching ---------------------------
    //
    // Single-key burst traffic into a single worker, submitted open-loop
    // (all tickets in flight before the first wait) so the queue holds
    // same-key neighbours and the drain coalesces them into fused
    // `run_batch_into` executions: operands staged once per term,
    // per-term configuration amortized over the whole batch.  Compare
    // against `serve_throughput_1w` (same worker count, mixed keys, no
    // fusion opportunity) for the batching win.
    {
        use deinsum::{ServeRequest, Server, Ticket};
        let n = if tiny { 8 } else { 16 };
        let r = 4usize;
        let expr = "ijk,ja,ka->ia";
        let shapes = vec![vec![n, n, n], vec![n, r], vec![n, r]];
        let batch = if tiny { 16usize } else { 64 };
        let inputs: Vec<std::sync::Arc<Vec<Tensor>>> = (0..batch)
            .map(|q| {
                std::sync::Arc::new(
                    shapes
                        .iter()
                        .enumerate()
                        .map(|(j, s)| Tensor::random(s, (131 + 5 * q + j) as u64))
                        .collect::<Vec<_>>(),
                )
            })
            .collect();
        let shape = format!("1 key x {batch} reqs n={n}");
        let session = Session::builder().ranks(8).kernel_config(cfg).build().unwrap();
        let server =
            Server::builder(session).workers(1).queue_capacity(batch + 1).build();
        let mut dests: Vec<Option<Tensor>> = (0..batch)
            .map(|_| Some(Tensor::zeros(&Server::output_dims(expr, &shapes).unwrap())))
            .collect();
        let drive = |dests: &mut Vec<Option<Tensor>>| {
            let tickets: Vec<Ticket> = (0..batch)
                .map(|q| {
                    server
                        .submit(ServeRequest {
                            tenant: "bench-batched".into(),
                            expr: expr.into(),
                            shapes: shapes.clone(),
                            inputs: std::sync::Arc::clone(&inputs[q]),
                            dest: dests[q].take().unwrap(),
                        })
                        .unwrap()
                })
                .collect();
            for (q, t) in tickets.into_iter().enumerate() {
                dests[q] = Some(t.wait().unwrap().output);
            }
        };
        drive(&mut dests); // warm the program + per-member batch buffers
        let (med, _, _) = common::time_median(reps, || drive(&mut dests));
        let rps = batch as f64 / med;
        let st = server.stats();
        println!(
            "serve batched {shape} 1w: {} per burst ({rps:.0} req/s, {} fused members, p99 {:.6}s)",
            common::fmt_s(med),
            st.batched,
            st.p99_latency_s,
        );
        record(&mut records, "serve_throughput_batched", &shape, med, None, None);
    }

    // --- serving admission: try_submit + bounded wait round trip ---------------
    //
    // The 0.7.0 fault-tolerant admission path (dims validation against
    // the memoized cache, non-blocking queue reservation, ticket with a
    // deadline-bounded wait) timed as a closed-loop round trip on a warm
    // single-worker server.  Tracks the robustness layer's overhead: the
    // typed-error seam must stay invisible next to the run itself.
    {
        use deinsum::{ServeRequest, Server};
        let n = if tiny { 8 } else { 16 };
        let shapes = vec![vec![n, n], vec![n, n]];
        let ins = std::sync::Arc::new(vec![
            Tensor::random(&[n, n], 91),
            Tensor::random(&[n, n], 92),
        ]);
        let session = Session::builder().ranks(8).kernel_config(cfg).build().unwrap();
        let server = Server::builder(session).workers(1).build();
        let mut dest =
            Some(Tensor::zeros(&Server::output_dims("ij,jk->ik", &shapes).unwrap()));
        let mut round_trip = || {
            let ticket = server
                .try_submit(ServeRequest {
                    tenant: "admission".into(),
                    expr: "ij,jk->ik".into(),
                    shapes: shapes.clone(),
                    inputs: std::sync::Arc::clone(&ins),
                    dest: dest.take().unwrap(),
                })
                .expect("a single closed-loop request never fills the queue");
            dest = Some(
                ticket
                    .wait_timeout(std::time::Duration::from_secs(30))
                    .expect("served well within the bound")
                    .output,
            );
        };
        round_trip(); // warm the program + recycled paths
        let inner = 32usize;
        let (med, _, _) = common::time_median(reps, || {
            for _ in 0..inner {
                round_trip();
            }
        });
        let per_req = med / inner as f64;
        println!(
            "serve admission (try_submit + wait_timeout, 1w closed loop): {} per request",
            common::fmt_s(per_req)
        );
        record(
            &mut records,
            "serve_admission",
            &format!("ij,jk->ik n={n} 1w"),
            per_req,
            None,
            None,
        );
    }

    // --- fuzz campaign throughput (differential harness, src/fuzz) -------------
    //
    // Cases/sec over the fixed-seed tiny corpus: each case is generated,
    // evaluated by the dense oracle, and compiled + run (run and dirty
    // run_into) at ranks {1,4,8} — so this entry tracks the end-to-end
    // cost of the correctness harness itself, and the timed region
    // doubles as a zero-bug assertion on every bench run.
    {
        use deinsum::fuzz;
        let seed = 20260808u64;
        let cases: u64 = if tiny { 16 } else { 64 };
        let (med, _, _) = common::time_median(reps, || {
            let rep = fuzz::campaign(seed, cases, fuzz::DEFAULT_RANKS);
            assert!(rep.bugs.is_empty(), "fuzz campaign found bugs:\n{}", rep.corpus());
        });
        let cps = cases as f64 / med;
        let shape = format!("seed {seed} x {cases} cases ranks 1,4,8");
        println!("fuzz campaign {shape}: {} ({cps:.1} cases/s)", common::fmt_s(med));
        record(&mut records, "fuzz_campaign", &shape, med, None, None);
    }

    // --- machine-readable trajectory ------------------------------------------
    let json = format!(
        "{{\n  \"config\": {{\"mc\": {}, \"kc\": {}, \"nc\": {}, \"threads\": {}, \"reps\": {reps}}},\n  \"results\": [\n{}\n  ]\n}}\n",
        cfg.mc,
        cfg.kc,
        cfg.nc,
        cfg.threads,
        records.join(",\n")
    );
    let path =
        std::env::var("DEINSUM_BENCH_JSON").unwrap_or_else(|_| "BENCH_hotpath.json".to_string());
    match std::fs::write(&path, &json) {
        Ok(()) => println!("# wrote {path}"),
        Err(e) => eprintln!("# could not write {path}: {e}"),
    }
}
