"""L2 correctness: local-tile pipelines (permute/fold + kernels) vs oracle."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

RNG = np.random.default_rng(99)


def randn(*shape):
    return jnp.asarray(RNG.standard_normal(shape).astype(np.float32))


class TestLocalMttkrpModes:
    @pytest.mark.parametrize("mode", [0, 1, 2])
    def test_order3_all_modes(self, mode):
        x = randn(10, 12, 14)
        fs = [randn(d, 6) for d in x.shape]
        inputs = [fs[m] for m in range(3) if m != mode]
        got = model.local_mttkrp(x, inputs, mode=mode)
        want = ref.mttkrp(x, fs, mode)
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)

    @pytest.mark.parametrize("mode", [0, 2, 4])
    def test_order5_paper_modes(self, mode):
        x = randn(6, 5, 4, 5, 6)
        fs = [randn(d, 4) for d in x.shape]
        inputs = [fs[m] for m in range(5) if m != mode]
        got = model.local_mttkrp(x, inputs, mode=mode)
        want = ref.mttkrp(x, fs, mode)
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)


class TestLocalTtm:
    @pytest.mark.parametrize("mode", [0, 1, 2])
    def test_order3(self, mode):
        x = randn(9, 10, 11)
        u = randn(x.shape[mode], 5)
        got = model.local_ttm(x, u, mode)
        want = ref.ttm(x, u, mode)
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)

    @settings(max_examples=15, deadline=None)
    @given(
        dims=st.tuples(st.integers(2, 10), st.integers(2, 10), st.integers(2, 10)),
        mode=st.integers(0, 2),
        r=st.integers(1, 6),
    )
    def test_hypothesis(self, dims, mode, r):
        x = randn(*dims)
        u = randn(dims[mode], r)
        np.testing.assert_allclose(
            model.local_ttm(x, u, mode), ref.ttm(x, u, mode), rtol=1e-3, atol=1e-4
        )


class TestLocalTtmc:
    def test_order5_mode0(self):
        # TTMc-05-M0 from Table IV (scaled down).
        x = randn(6, 5, 4, 5, 6)
        fs = [randn(d, 3) for d in x.shape]
        got = model.local_ttmc(x, fs, mode=0)
        want = ref.ttmc(x, fs, mode=0)
        assert got.shape == (6, 3, 3, 3, 3)
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)

    @pytest.mark.parametrize("mode", [0, 1, 2])
    def test_order3_modes(self, mode):
        x = randn(7, 8, 9)
        fs = [randn(d, 4) for d in x.shape]
        got = model.local_ttmc(x, fs, mode=mode)
        np.testing.assert_allclose(
            got, ref.ttmc(x, fs, mode=mode), rtol=1e-3, atol=1e-4
        )


class TestKrpFlat:
    def test_matches_two_step_pipeline(self):
        u0, u1 = randn(6, 4), randn(7, 4)
        x = randn(5, 6, 7)
        flat = model.local_krp_flat(u0, u1)
        xmat = np.asarray(x).reshape(5, 42)
        out = xmat @ np.asarray(flat)
        want = ref.mttkrp(x, [None, u0, u1], 0)
        np.testing.assert_allclose(out, want, rtol=1e-3, atol=1e-4)


class TestBuilders:
    def test_build_gemm_runs(self):
        fn, specs = model.build_gemm(16, 8, 12)
        a, b = randn(16, 8), randn(8, 12)
        (out,) = fn(a, b)
        np.testing.assert_allclose(out, ref.gemm(a, b), rtol=1e-4)

    def test_build_mttkrp_runs(self):
        fn, specs = model.build_mttkrp((8, 8, 8), 4)
        x = randn(8, 8, 8)
        fs = [randn(8, 4), randn(8, 4)]
        (out,) = fn(x, *fs)
        np.testing.assert_allclose(
            out, ref.mttkrp(x, [None] + fs, 0), rtol=1e-3, atol=1e-4
        )

    def test_build_ttmc_runs(self):
        fn, specs = model.build_ttmc((5, 6, 7), (3, 3, 3), mode=1)
        x = randn(5, 6, 7)
        fs = [randn(5, 3), randn(7, 3)]
        (out,) = fn(x, *fs)
        all_fs = [fs[0], None, fs[1]]
        np.testing.assert_allclose(
            out, ref.ttmc(x, all_fs, mode=1), rtol=1e-3, atol=1e-4
        )

    def test_specs_match_inputs(self):
        fn, specs = model.build_mttkrp((8, 6, 4), 5)
        assert [tuple(s.shape) for s in specs] == [(8, 6, 4), (6, 5), (4, 5)]
