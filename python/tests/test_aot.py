"""AOT path: lowering produces parseable HLO text + a coherent manifest."""

import json
import os
import subprocess
import sys

import numpy as np
import jax.numpy as jnp
import pytest

from compile import aot, model


class TestToHloText:
    def test_gemm_lowering_nonempty(self):
        fn, specs = model.build_gemm(8, 8, 8)
        text = aot.to_hlo_text(fn.lower(*specs))
        assert "HloModule" in text
        assert "ENTRY" in text
        # return_tuple=True -> root is a tuple
        assert "tuple" in text

    def test_mttkrp_lowering_has_dot(self):
        fn, specs = model.build_mttkrp((8, 8, 8), 4)
        text = aot.to_hlo_text(fn.lower(*specs))
        assert "HloModule" in text
        # the fused kernel's MXU contraction must survive lowering
        assert "dot(" in text or "dot." in text

    def test_parameter_count_matches_specs(self):
        fn, specs = model.build_mttkrp((8, 6, 4), 5)
        text = aot.to_hlo_text(fn.lower(*specs))
        # Count parameters of the ENTRY computation only (while-loop bodies
        # have their own).
        entry = text[text.index("ENTRY") :]
        assert entry.count("parameter(") == len(specs)


class TestVariantNaming:
    def test_names_unique(self):
        variants = aot.variant_list(quick=False)
        names = [aot.variant_name(v, "f32") for v in variants]
        assert len(names) == len(set(names))

    def test_quick_subset_of_full(self):
        quick = {aot.variant_name(v, "f32") for v in aot.variant_list(quick=True)}
        full = {aot.variant_name(v, "f32") for v in aot.variant_list(quick=False)}
        assert quick <= full

    def test_build_dispatch_all_ops(self):
        for spec in aot.variant_list(quick=True):
            fn, arg_specs = aot.build(spec, jnp.float32)
            assert len(arg_specs) >= 2


class TestEndToEnd:
    def test_quick_aot_writes_manifest(self, tmp_path):
        out = tmp_path / "artifacts"
        subprocess.run(
            [sys.executable, "-m", "compile.aot", "--out-dir", str(out), "--quick"],
            check=True,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        )
        manifest = json.loads((out / "manifest.json").read_text())
        assert manifest["format"] == "hlo-text-v1"
        assert len(manifest["variants"]) > 0
        for v in manifest["variants"]:
            p = out / v["file"]
            assert p.exists(), v["name"]
            head = p.read_text()[:200]
            assert "HloModule" in head
