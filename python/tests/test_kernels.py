"""L1 correctness: every Pallas kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes (and block raggedness) — the CORE correctness
signal for the artifacts the Rust runtime executes.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gemm_pallas, krp_pallas, mttkrp_pallas
from compile.kernels import ref
from compile.kernels.gemm import optimal_gemm_tiles
from compile.kernels.mttkrp import optimal_mttkrp_tiles, vmem_footprint

RNG = np.random.default_rng(1234)


def randn(*shape, dtype=np.float32):
    return jnp.asarray(RNG.standard_normal(shape).astype(dtype))


# ---------------------------------------------------------------- GEMM ----


class TestGemm:
    def test_basic(self):
        a, b = randn(32, 16), randn(16, 24)
        np.testing.assert_allclose(
            gemm_pallas(a, b, blocks=(8, 8, 8)), ref.gemm(a, b), rtol=1e-4, atol=1e-5
        )

    def test_single_block(self):
        a, b = randn(8, 8), randn(8, 8)
        np.testing.assert_allclose(
            gemm_pallas(a, b, blocks=(8, 8, 8)), ref.gemm(a, b), rtol=1e-4
        )

    def test_ragged_falls_back_to_full_dim(self):
        a, b = randn(30, 14), randn(14, 18)
        np.testing.assert_allclose(
            gemm_pallas(a, b, blocks=(8, 8, 8)), ref.gemm(a, b), rtol=1e-4
        )

    def test_default_blocks(self):
        a, b = randn(64, 64), randn(64, 64)
        np.testing.assert_allclose(gemm_pallas(a, b), ref.gemm(a, b), rtol=1e-4)

    @settings(max_examples=25, deadline=None)
    @given(
        m=st.integers(1, 40),
        k=st.integers(1, 40),
        n=st.integers(1, 40),
        bm=st.sampled_from([8, 16]),
    )
    def test_hypothesis_shapes(self, m, k, n, bm):
        a, b = randn(m, k), randn(k, n)
        got = gemm_pallas(a, b, blocks=(bm, bm, bm))
        np.testing.assert_allclose(got, ref.gemm(a, b), rtol=1e-3, atol=1e-4)

    def test_optimal_tiles_fit_budget(self):
        for s in (1 << 12, 1 << 16, 1 << 20):
            bm, bk, bn = optimal_gemm_tiles(s, 1 << 20, 1 << 20, 1 << 20)
            # three tiles together must fit in S (the sqrt(S/3) law)
            assert bm * bk + bk * bn + bm * bn <= s
            # and not be trivially small: within 2x of the bound
            assert 3 * bm * bk >= s / 4


# ----------------------------------------------------------------- KRP ----


class TestKrp:
    def test_basic(self):
        u0, u1 = randn(16, 8), randn(24, 8)
        np.testing.assert_allclose(
            krp_pallas(u0, u1, blocks=(8, 8)), ref.krp(u0, u1), rtol=1e-5
        )

    @settings(max_examples=20, deadline=None)
    @given(i0=st.integers(1, 32), i1=st.integers(1, 32), r=st.integers(1, 12))
    def test_hypothesis_shapes(self, i0, i1, r):
        u0, u1 = randn(i0, r), randn(i1, r)
        got = krp_pallas(u0, u1, blocks=(8, 8))
        np.testing.assert_allclose(got, ref.krp(u0, u1), rtol=1e-4, atol=1e-5)

    def test_flattened_matches_chain(self):
        u0, u1 = randn(6, 4), randn(5, 4)
        flat = np.asarray(krp_pallas(u0, u1)).reshape(30, 4)
        np.testing.assert_allclose(
            flat, np.asarray(ref.krp_chain([u0, u1])).reshape(30, 4), rtol=1e-5
        )


# -------------------------------------------------------------- MTTKRP ----


class TestMttkrpOrder3:
    def test_basic(self):
        x = randn(16, 12, 20)
        fs = [randn(12, 6), randn(20, 6)]
        got = mttkrp_pallas(x, fs, blocks=(8, 6, 10))
        np.testing.assert_allclose(
            got, ref.mttkrp(x, [None] + fs, 0), rtol=1e-3, atol=1e-4
        )

    def test_single_block(self):
        x = randn(8, 8, 8)
        fs = [randn(8, 4), randn(8, 4)]
        got = mttkrp_pallas(x, fs, blocks=(8, 8, 8))
        np.testing.assert_allclose(
            got, ref.mttkrp(x, [None] + fs, 0), rtol=1e-3, atol=1e-4
        )

    def test_default_paper_tiling(self):
        x = randn(32, 32, 32)
        fs = [randn(32, 24), randn(32, 24)]
        got = mttkrp_pallas(x, fs, vmem=1 << 12)
        np.testing.assert_allclose(
            got, ref.mttkrp(x, [None] + fs, 0), rtol=1e-3, atol=1e-4
        )

    @settings(max_examples=20, deadline=None)
    @given(
        ni=st.integers(1, 24),
        nj=st.integers(1, 24),
        nk=st.integers(1, 24),
        r=st.integers(1, 8),
    )
    def test_hypothesis_shapes(self, ni, nj, nk, r):
        x = randn(ni, nj, nk)
        fs = [randn(nj, r), randn(nk, r)]
        got = mttkrp_pallas(x, fs, blocks=(8, 8, 8))
        np.testing.assert_allclose(
            got, ref.mttkrp(x, [None] + fs, 0), rtol=1e-3, atol=1e-4
        )

    def test_agrees_with_two_step(self):
        x = randn(10, 11, 12)
        fs = [randn(11, 5), randn(12, 5)]
        fused = mttkrp_pallas(x, fs, blocks=(8, 8, 8))
        two = ref.mttkrp_two_step(x, [None] + fs, 0)
        np.testing.assert_allclose(fused, two, rtol=1e-3, atol=1e-4)


class TestMttkrpOrder5:
    def test_basic(self):
        x = randn(8, 6, 4, 6, 4)
        fs = [randn(d, 5) for d in (6, 4, 6, 4)]
        got = mttkrp_pallas(x, fs, blocks=(4, 3, 2, 3, 2))
        np.testing.assert_allclose(
            got, ref.mttkrp(x, [None] + fs, 0), rtol=1e-3, atol=1e-4
        )

    @settings(max_examples=10, deadline=None)
    @given(dims=st.tuples(*[st.integers(1, 8)] * 5), r=st.integers(1, 6))
    def test_hypothesis_shapes(self, dims, r):
        x = randn(*dims)
        fs = [randn(d, r) for d in dims[1:]]
        got = mttkrp_pallas(x, fs, blocks=(4,) * 5)
        np.testing.assert_allclose(
            got, ref.mttkrp(x, [None] + fs, 0), rtol=1e-3, atol=1e-4
        )


class TestOptimalTiling:
    def test_order3_closed_form(self):
        # Paper Sec. IV-E: I = J = K = S^{1/3} (lane-rounded).
        s = 1 << 18
        tiles = optimal_mttkrp_tiles(s, (10**6,) * 3, 24)
        cube = round(s ** (1 / 3))
        assert all(abs(t - cube) <= 8 for t in tiles)

    def test_x_tile_fills_budget(self):
        s = 1 << 15
        tiles = optimal_mttkrp_tiles(s, (10**6,) * 3, 24)
        vol = tiles[0] * tiles[1] * tiles[2]
        assert s / 3 <= vol <= 2 * s

    def test_vmem_footprint_fields(self):
        fp = vmem_footprint((64, 64, 64), 24)
        assert fp["x_tile_bytes"] == 64**3 * 4
        assert fp["out_bytes"] == 64 * 24 * 4
        assert fp["arithmetic_intensity"] > 0
        # fused kernel: MXU flops per step = 2 * Bi * Bj*Bk * R
        assert fp["mxu_flops_per_step"] == 2 * 64 * 64 * 64 * 24


@pytest.mark.parametrize("dtype", [np.float32])
def test_dtype_roundtrip(dtype):
    x = randn(8, 8, 8, dtype=dtype)
    fs = [randn(8, 4, dtype=dtype), randn(8, 4, dtype=dtype)]
    got = mttkrp_pallas(x, fs, blocks=(8, 8, 8))
    assert np.asarray(got).dtype == dtype
