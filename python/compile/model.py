"""L2 JAX compute graphs: the local-tile operations Deinsum schedules.

Each function here is the *per-rank* computation for one term of a
distributed plan (paper Sec. II-D): the Rust coordinator assigns every MPI
rank a block of the iteration space, and the rank's local work is one of
these ops on its tiles.  They call the L1 Pallas kernels so that the AOT
lowering produces a single HLO module containing the whole local pipeline
(permute -> fold -> kernel -> fold back), i.e. the cross-statement fusion
the paper performs at the IR level.

Build-time only: `aot.py` lowers shape-specialized instances of these to
HLO text; Python never runs on the request path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.gemm import gemm_pallas
from .kernels.krp import krp_pallas
from .kernels.mttkrp import mttkrp_pallas


def local_gemm(a, b):
    """Local tile GEMM (MM chains, and the MM term of fused programs)."""
    return gemm_pallas(a, b)


def local_mttkrp(x, factors, mode=0):
    """Local fused MTTKRP in any mode.

    Permutes X so `mode` leads (the paper's HPTT transposition), then runs
    the fused mode-0 Pallas kernel.  The permutation lowers into the same
    HLO module, so the artifact is one self-contained local pipeline.
    """
    order = x.ndim
    if mode != 0:
        perm = [mode] + [m for m in range(order) if m != mode]
        x = jnp.transpose(x, perm)
    return mttkrp_pallas(x, list(factors))


def local_krp_flat(u0, u1):
    """Baseline-only: materialized KRP, matricized to (I0*I1, R)."""
    i0, r = u0.shape
    i1, _ = u1.shape
    return krp_pallas(u0, u1).reshape(i0 * i1, r)


def local_ttm(x, u, mode):
    """Local TTM: fold X so `mode` is last, GEMM against U, fold back.

    This is the fold-to-BLAS lowering of Sec. III-B; the GEMM is the
    Pallas kernel, the transposes lower to HLO transpose ops (HPTT's role).
    """
    order = x.ndim
    perm = [m for m in range(order) if m != mode] + [mode]
    xt = jnp.transpose(x, perm)
    lead = xt.shape[:-1]
    folded = xt.reshape(-1, x.shape[mode])
    out = gemm_pallas(folded, u)  # (prod lead, R)
    r = u.shape[1]
    out = out.reshape(lead + (r,))
    inv = [0] * order
    for pos, m in enumerate(perm):
        inv[m] = pos
    return jnp.transpose(out, inv)


def local_ttmc(x, factors, mode):
    """Local TTM chain: apply every factor except `mode`'s, in order.

    Contracting the largest dims first minimizes intermediate sizes for the
    paper's benchmark shapes (all I equal, all R equal, R < I), matching
    the FLOP-optimal binary decomposition opt_einsum finds.
    """
    out = x
    for m in range(x.ndim):
        if m == mode:
            continue
        out = local_ttm(out, factors[m], m)
    return out


# ---------------------------------------------------------------------------
# Shape-specialized builders for AOT lowering (consumed by aot.py).
# Each returns (jitted_fn, arg_specs); fn returns a 1-tuple (the Rust side
# unwraps with to_tuple1, see /opt/xla-example).
# ---------------------------------------------------------------------------


def build_gemm(m: int, k: int, n: int, dtype=jnp.float32):
    def fn(a, b):
        return (local_gemm(a, b),)

    specs = (
        jax.ShapeDtypeStruct((m, k), dtype),
        jax.ShapeDtypeStruct((k, n), dtype),
    )
    return jax.jit(fn), specs


def build_mttkrp(dims: tuple[int, ...], r: int, dtype=jnp.float32):
    """Mode-0 fused MTTKRP over `dims` with rank `r` (Rust permutes for
    other modes before dispatch, mirroring local_mttkrp)."""

    def fn(x, *factors):
        return (local_mttkrp(x, factors, mode=0),)

    specs = (jax.ShapeDtypeStruct(tuple(dims), dtype),) + tuple(
        jax.ShapeDtypeStruct((d, r), dtype) for d in dims[1:]
    )
    return jax.jit(fn), specs


def build_krp(i0: int, i1: int, r: int, dtype=jnp.float32):
    def fn(u0, u1):
        return (local_krp_flat(u0, u1),)

    specs = (
        jax.ShapeDtypeStruct((i0, r), dtype),
        jax.ShapeDtypeStruct((i1, r), dtype),
    )
    return jax.jit(fn), specs


def build_ttmc(dims: tuple[int, ...], rs: tuple[int, ...], mode: int, dtype=jnp.float32):
    """TTMc over `dims`, ranks `rs` (rs[mode] ignored)."""

    def fn(x, *factors):
        fs = list(factors)
        fs.insert(mode, None)
        return (local_ttmc(x, fs, mode),)

    specs = (jax.ShapeDtypeStruct(tuple(dims), dtype),) + tuple(
        jax.ShapeDtypeStruct((dims[m], rs[m]), dtype)
        for m in range(len(dims))
        if m != mode
    )
    return jax.jit(fn), specs
