"""Fused MTTKRP Pallas kernel — the paper's compute hot-spot.

Implements the mode-0, order-N Matricized Tensor Times Khatri-Rao Product

    i0 i1 ... i_{N-1}, i1 r, ..., i_{N-1} r  ->  i0 r

as a *single fused* kernel: the Khatri-Rao product of the factor tiles is
formed in VMEM and immediately contracted against the matricized X tile on
the MXU, never materializing the KRP in HBM.  This is exactly the fusion
the paper's SOAP analysis proves I/O-optimal (Sec. IV-E): the two-step
KRP-then-GEMM formulation used by CTF-like libraries moves an extra
S^{1/6} factor of data.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's
I/O-optimal tiling I = J = K = S^{1/3}, L = S^{2/3}/2 becomes the BlockSpec
HBM<->VMEM schedule.  Each grid step loads one (Bi, B1, ..., B_{N-1})
X-block plus skinny (Bm, R) factor blocks; the KRP is VPU elementwise work
and the contraction is a (Bi, prod Bm) x (prod Bm, R) MXU matmul
accumulating into a VMEM-resident (Bi, R) output block.

Other modes are handled at L2 by a mode permutation of X (the paper does
the same with HPTT transpositions).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_LANE = 8


def optimal_mttkrp_tiles(s: int, dims: tuple[int, ...], r: int) -> tuple[int, ...]:
    """Paper Sec. IV-E tiling, generalized to order N.

    Order-3 closed form: I = J = K = S^{1/3} (the rank dim L = S^{2/3}/2 is
    in practice >> R = 24, so R is never tiled).  For order N we give each
    tensor dim an equal share S^{1/N} of the X-tile budget, which recovers
    the closed form at N = 3 and keeps the X tile (the dominant access set)
    at exactly S elements.
    """
    n = len(dims)
    b = max(1, int(round(s ** (1.0 / n))))
    b = max(_LANE, (b // _LANE) * _LANE)
    return tuple(min(b, d) for d in dims)


def _make_kernel(n_red: int):
    """Kernel body for an order-(n_red + 1) MTTKRP (n_red factor inputs)."""

    def kernel(*refs):
        x_ref = refs[0]
        f_refs = refs[1 : 1 + n_red]
        o_ref = refs[1 + n_red]

        first = pl.program_id(1) == 0
        for ax in range(2, 1 + n_red):
            first = jnp.logical_and(first, pl.program_id(ax) == 0)

        @pl.when(first)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)

        # KRP of the factor tiles, formed in VMEM (VPU elementwise).
        k = f_refs[0][...]
        for f in f_refs[1:]:
            k = k[..., None, :] * f[...][(None,) * (k.ndim - 1) + (slice(None), slice(None))]
        r = k.shape[-1]
        k_mat = k.reshape(-1, r)
        # Matricized X tile against the KRP tile on the MXU.
        bi = x_ref.shape[0]
        x_mat = x_ref[...].reshape(bi, -1)
        o_ref[...] += jnp.dot(x_mat, k_mat, preferred_element_type=o_ref.dtype)

    return kernel


def mttkrp_pallas(x, factors, *, blocks=None, vmem=1 << 17):
    """out[i0, r] = sum over i1..i_{N-1} of X[i0,...,i_{N-1}] * prod_m U_m[i_m, r].

    x: order-N tensor; factors: list of N-1 matrices (I_m, R) for modes
    1..N-1 (mode-0 MTTKRP; permute X at L2 for other modes).
    blocks: optional per-mode tile sizes; defaults to the paper-optimal
    tiling for a fast memory of `vmem` elements.
    """
    order = x.ndim
    n_red = order - 1
    assert len(factors) == n_red, f"need {n_red} factors, got {len(factors)}"
    r = factors[0].shape[1]
    for m, f in enumerate(factors):
        assert f.shape == (x.shape[m + 1], r), (
            f"factor {m} shape {f.shape} != {(x.shape[m + 1], r)}"
        )
    if blocks is None:
        blocks = optimal_mttkrp_tiles(vmem, x.shape, r)
    blocks = list(blocks)
    for ax in range(order):
        blocks[ax] = min(blocks[ax], x.shape[ax])
        if x.shape[ax] % blocks[ax]:
            blocks[ax] = x.shape[ax]
    grid = tuple(x.shape[ax] // blocks[ax] for ax in range(order))

    def x_map(*ids):
        return ids

    def factor_map(m):
        # factor m (0-based over reduction modes) is indexed by grid axis m+1.
        return lambda *ids: (ids[m + 1], 0)

    in_specs = [pl.BlockSpec(tuple(blocks), x_map)]
    for m in range(n_red):
        in_specs.append(pl.BlockSpec((blocks[m + 1], r), factor_map(m)))

    return pl.pallas_call(
        _make_kernel(n_red),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            (blocks[0], r), lambda *ids: (ids[0],) + (0,)
        ),
        out_shape=jax.ShapeDtypeStruct((x.shape[0], r), x.dtype),
        interpret=True,
    )(x, *factors)


def vmem_footprint(blocks: tuple[int, ...], r: int, itemsize: int = 4) -> dict:
    """Bytes resident in VMEM per grid step — the TPU perf estimate input
    recorded in EXPERIMENTS.md (interpret=True gives no hardware timing)."""
    x_tile = 1
    for b in blocks:
        x_tile *= b
    red = blocks[1:]
    krp = 1
    for b in red:
        krp *= b
    factors = sum(b * r for b in red)
    out = blocks[0] * r
    total = (x_tile + factors + krp * r + out) * itemsize
    # MXU work per step: (Bi x prod(red)) @ (prod(red) x R)
    flops = 2 * blocks[0] * krp * r
    return {
        "x_tile_bytes": x_tile * itemsize,
        "factor_bytes": factors * itemsize,
        "krp_scratch_bytes": krp * r * itemsize,
        "out_bytes": out * itemsize,
        "total_bytes": total,
        "mxu_flops_per_step": flops,
        "arithmetic_intensity": flops / max(1, total),
    }


def make_mttkrp(dims: tuple[int, ...], r: int, dtype=jnp.float32):
    """Shape-specialized jittable fused MTTKRP for AOT lowering."""

    def fn(x, *factors):
        return (mttkrp_pallas(x, list(factors)),)

    specs = (jax.ShapeDtypeStruct(tuple(dims), dtype),) + tuple(
        jax.ShapeDtypeStruct((d, r), dtype) for d in dims[1:]
    )
    return jax.jit(fn), specs
