"""Tiled GEMM Pallas kernel.

The local work of every binary contraction that is *not* a fused MTTKRP
(TTM, TTMc stages, MM-chain stages, TDOT) folds to a matmul after a
mode permutation (paper Sec. III-B), so this single kernel is the MXU
workhorse.  Block sizes follow the classical I/O-optimal square tiling
(rho = sqrt(S)/2, Sec. IV-A): Bm = Bn = Bk = sqrt(S/3) rounded to the MXU
lane multiple.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-friendly rounding: real TPU tiles are multiples of (8, 128); under
# interpret=True any size works, but we keep the discipline so the
# BlockSpecs describe a realizable VMEM schedule.
_LANE = 8


def _round_block(b: int, n: int) -> int:
    """Round block size to a multiple of _LANE, clamped to [1, n]."""
    b = max(_LANE, (b // _LANE) * _LANE)
    return min(b, n)


def optimal_gemm_tiles(s: int, m: int, k: int, n: int) -> tuple[int, int, int]:
    """Square I/O-optimal GEMM tiles: three equal blocks filling fast
    memory S (classical sqrt(S/3) tiling)."""
    b = max(1, int((s / 3) ** 0.5))
    return (_round_block(b, m), _round_block(b, k), _round_block(b, n))


def _gemm_kernel(a_ref, b_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=o_ref.dtype
    )


def gemm_pallas(a, b, *, blocks=None, vmem=1 << 17):
    """C[m,n] = A[m,k] @ B[k,n] as a tiled Pallas kernel.

    blocks: optional (Bm, Bk, Bn); defaults to the I/O-optimal square tile
    for a fast memory of `vmem` elements.  Dimensions must divide evenly
    (the Rust coordinator pads tiles to bucket shapes before dispatch).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"contraction mismatch {k} != {k2}"
    if blocks is None:
        blocks = optimal_gemm_tiles(vmem, m, k, n)
    bm, bk, bn = (min(blocks[0], m), min(blocks[1], k), min(blocks[2], n))
    # Fall back to full extent when the block does not divide the dim;
    # keeps the kernel exact for ragged sizes (interpret mode).
    if m % bm:
        bm = m
    if k % bk:
        bk = k
    if n % bn:
        bn = n
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _gemm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        interpret=True,
    )(a, b)


def make_gemm(m: int, k: int, n: int, dtype=jnp.float32):
    """Shape-specialized jittable GEMM for AOT lowering."""

    @functools.partial(jax.jit, static_argnums=())
    def fn(a, b):
        return (gemm_pallas(a, b),)

    specs = (
        jax.ShapeDtypeStruct((m, k), dtype),
        jax.ShapeDtypeStruct((k, n), dtype),
    )
    return fn, specs
