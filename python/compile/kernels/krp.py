"""Khatri-Rao product Pallas kernel (explicit materialization).

Only the CTF-like two-step baseline materializes the KRP (paper Sec. IV-E
shows this is communication-suboptimal); Deinsum's own schedule fuses it
into the MTTKRP kernel.  We still ship it as a first-class kernel because
the baseline must be a faithful comparator.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _krp_kernel(u0_ref, u1_ref, o_ref):
    o_ref[...] = u0_ref[...][:, None, :] * u1_ref[...][None, :, :]


def krp_pallas(u0, u1, *, blocks=None):
    """out[i0, i1, r] = u0[i0, r] * u1[i1, r] (unflattened KRP).

    VPU-only elementwise work; blocked over both row dims so each grid step
    holds (B0 + B1 + B0*B1) * R elements in VMEM.
    """
    i0, r = u0.shape
    i1, r2 = u1.shape
    assert r == r2, f"rank mismatch {r} != {r2}"
    if blocks is None:
        blocks = (min(128, i0), min(128, i1))
    b0, b1 = (min(blocks[0], i0), min(blocks[1], i1))
    if i0 % b0:
        b0 = i0
    if i1 % b1:
        b1 = i1
    grid = (i0 // b0, i1 // b1)
    return pl.pallas_call(
        _krp_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b0, r), lambda i, j: (i, 0)),
            pl.BlockSpec((b1, r), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((b0, b1, r), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((i0, i1, r), u0.dtype),
        interpret=True,
    )(u0, u1)


def make_krp(i0: int, i1: int, r: int, dtype=jnp.float32):
    """Shape-specialized jittable KRP for AOT lowering (flattened output,
    matching the baseline's matricized use)."""

    def fn(u0, u1):
        return (krp_pallas(u0, u1).reshape(i0 * i1, r),)

    specs = (
        jax.ShapeDtypeStruct((i0, r), dtype),
        jax.ShapeDtypeStruct((i1, r), dtype),
    )
    return jax.jit(fn), specs
