"""L1 Pallas kernels for Deinsum's local-tile hot spots.

Three kernels cover every local computation the Rust coordinator schedules:

- ``mttkrp``  — the paper's headline fused kernel (KRP + TDOT in one pass,
  Sec. II-B / IV-E), tiled with the I/O-optimal block sizes.
- ``gemm``    — tiled matmul; TTM / TTMc / MM-chain local work folds to it.
- ``krp``     — explicit Khatri-Rao materialization, used only by the
  CTF-like two-step baseline.

All kernels are lowered with ``interpret=True`` (CPU PJRT cannot execute
Mosaic custom-calls); the BlockSpecs still express the HBM<->VMEM schedule
a real TPU lowering would use.
"""

from .gemm import gemm_pallas, make_gemm
from .krp import krp_pallas, make_krp
from .mttkrp import make_mttkrp, mttkrp_pallas, optimal_mttkrp_tiles

__all__ = [
    "gemm_pallas",
    "krp_pallas",
    "mttkrp_pallas",
    "make_gemm",
    "make_krp",
    "make_mttkrp",
    "optimal_mttkrp_tiles",
]
