"""Pure-jnp oracles for every L1 kernel.

These are the correctness references: the Pallas kernels (and, through the
AOT path, the HLO artifacts the Rust runtime executes) are asserted
allclose against these in python/tests/.  Each function mirrors a tensor
operation defined in the paper's Sec. III-B.
"""

from __future__ import annotations

import string

import jax.numpy as jnp

# Index alphabet used when synthesizing einsum strings for order-n ops.
_IDX = string.ascii_lowercase


def gemm(a, b):
    """Matrix-matrix product, ij,jk->ik."""
    return jnp.matmul(a, b)


def krp(u0, u1):
    """Khatri-Rao product (column-wise Kronecker), i0r,i1r->(i0 i1)r.

    Returns the *unflattened* order-3 form i0,i1,r; callers that need the
    matricized (I0*I1, R) form reshape it themselves (paper Sec. III-B).
    """
    return u0[:, None, :] * u1[None, :, :]


def krp_chain(factors):
    """KRP of N matrices, kept unflattened: (I0, ..., I_{N-1}, R)."""
    out = factors[0]
    for f in factors[1:]:
        out = out[..., None, :] * f[(None,) * (out.ndim - 1) + (slice(None), slice(None))]
    return out


def ttm(x, u, mode):
    """Tensor-times-matrix in `mode`: contracts X's mode-`mode` fiber with
    U[I_mode, R] and places R in that mode."""
    order = x.ndim
    x_idx = _IDX[:order]
    r = _IDX[order]
    out_idx = x_idx[:mode] + r + x_idx[mode + 1 :]
    return jnp.einsum(f"{x_idx},{x_idx[mode]}{r}->{out_idx}", x, u)


def ttmc(x, factors, mode):
    """Mode-`mode` TTM chain: apply every factor except `mode`'s.

    factors: list of length order, factors[mode] is ignored (may be None).
    Output has shape (R_0, ..., I_mode, ..., R_{N-1}).
    """
    out = x
    for m in range(x.ndim):
        if m == mode:
            continue
        out = ttm(out, factors[m], m)
    return out


def mttkrp(x, factors, mode):
    """Mode-`mode` matricized tensor times Khatri-Rao product.

    factors: list of length order; factors[mode] ignored (may be None).
    Output: (I_mode, R).  Paper einsum (order-3 mode-0): ijk,ja,ka->ia.
    """
    order = x.ndim
    x_idx = _IDX[:order]
    r = _IDX[order]
    ins = [x_idx]
    ops = [x]
    for m in range(order):
        if m == mode:
            continue
        ins.append(x_idx[m] + r)
        ops.append(factors[m])
    return jnp.einsum(",".join(ins) + f"->{x_idx[mode]}{r}", *ops)


def mttkrp_two_step(x, factors, mode):
    """The communication-suboptimal two-step MTTKRP (explicit KRP
    materialization + GEMM) the paper argues against (Sec. IV-E).  Used as a
    semantics check for the baseline scheduler."""
    order = x.ndim
    rest = [m for m in range(order) if m != mode]
    k = krp_chain([factors[m] for m in rest])  # (I_r0, ..., R)
    r_dim = k.shape[-1]
    k_mat = k.reshape(-1, r_dim)
    # mode-n matricization of x: mode first, rest in order.
    perm = [mode] + rest
    x_mat = jnp.transpose(x, perm).reshape(x.shape[mode], -1)
    return x_mat @ k_mat


def tdot(x, y, axes):
    """Tensor dot product over the given axes pairs."""
    return jnp.tensordot(x, y, axes=axes)
