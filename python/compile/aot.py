"""AOT compiler: lower every local-tile kernel variant to HLO text.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs:
    artifacts/<name>.hlo.txt     one module per (op, shape, dtype) variant
    artifacts/manifest.json      variant metadata the Rust runtime indexes

The Rust runtime buckets ragged tile shapes up to the nearest variant by
zero-padding (safe for all multiply-add contractions) and falls back to
native Rust kernels when no bucket fits.

Usage: python -m compile.aot [--out-dir ../artifacts] [--quick]
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

DTYPES = {"f32": jnp.float32}
# Paper Table V: rank R = 24 for all MTTKRP/TTMc benchmarks.
RANK = 24


def to_hlo_text(lowered) -> str:
    """jax lowering -> XlaComputation -> HLO text (aot_recipe)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def variant_list(quick: bool = False):
    """The AOT variant set.

    GEMM buckets cover the MM-chain local tiles and the folded TTM stages;
    MTTKRP buckets cover the fused-kernel local tiles at the weak-scaling
    sizes of Table V (per-rank blocks of the initial 1024^3 / 1024^5 and
    60^5 problems across power-of-two grids).
    """
    v: list[dict] = []

    gemm_buckets = [64, 128, 256] if quick else [64, 128, 256, 512, 1024]
    for b in gemm_buckets:
        v.append({"op": "gemm", "m": b, "k": b, "n": b})
    # Skinny GEMMs: (tile, fold) x (fold, R) shapes from MTTKRP/TTMc folds
    # and the MM term of the worked example.
    for m in ([128, 256] if quick else [128, 256, 512, 1024]):
        v.append({"op": "gemm", "m": m, "k": m, "n": RANK})
        v.append({"op": "gemm", "m": m, "k": RANK, "n": RANK})

    mtt3 = [(64, 64, 64), (128, 128, 128)] if quick else [
        (64, 64, 64),
        (128, 128, 128),
        (256, 256, 256),
        (512, 256, 256),
        (512, 512, 512),
    ]
    for dims in mtt3:
        v.append({"op": "mttkrp", "dims": list(dims), "r": RANK})

    mtt5 = [(16,) * 5] if quick else [(16,) * 5, (32,) * 5, (32, 16, 16, 16, 16)]
    for dims in mtt5:
        v.append({"op": "mttkrp", "dims": list(dims), "r": RANK})

    krps = [(128, 128)] if quick else [(128, 128), (256, 256), (512, 512)]
    for i0, i1 in krps:
        v.append({"op": "krp", "i0": i0, "i1": i1, "r": RANK})

    ttmc5 = [(16,) * 5] if quick else [(16,) * 5, (32,) * 5, (60, 30, 30, 30, 30)]
    for dims in ttmc5:
        v.append({"op": "ttmc", "dims": list(dims), "rs": [RANK] * 5, "mode": 0})

    return v


def variant_name(spec: dict, dtype: str) -> str:
    op = spec["op"]
    if op == "gemm":
        core = f"{spec['m']}x{spec['k']}x{spec['n']}"
    elif op == "mttkrp":
        core = "x".join(map(str, spec["dims"])) + f"_r{spec['r']}"
    elif op == "krp":
        core = f"{spec['i0']}x{spec['i1']}_r{spec['r']}"
    elif op == "ttmc":
        core = "x".join(map(str, spec["dims"])) + "_m" + str(spec["mode"])
    else:
        raise ValueError(op)
    return f"{op}_{core}_{dtype}"


def build(spec: dict, dtype):
    op = spec["op"]
    if op == "gemm":
        return model.build_gemm(spec["m"], spec["k"], spec["n"], dtype)
    if op == "mttkrp":
        return model.build_mttkrp(tuple(spec["dims"]), spec["r"], dtype)
    if op == "krp":
        return model.build_krp(spec["i0"], spec["i1"], spec["r"], dtype)
    if op == "ttmc":
        return model.build_ttmc(
            tuple(spec["dims"]), tuple(spec["rs"]), spec["mode"], dtype
        )
    raise ValueError(op)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join("..", "artifacts"))
    ap.add_argument("--quick", action="store_true", help="small variant set")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"format": "hlo-text-v1", "variants": []}
    for spec in variant_list(args.quick):
        for dname, dtype in DTYPES.items():
            name = variant_name(spec, dname)
            fn, arg_specs = build(spec, dtype)
            lowered = fn.lower(*arg_specs)
            text = to_hlo_text(lowered)
            fname = f"{name}.hlo.txt"
            path = os.path.join(args.out_dir, fname)
            with open(path, "w") as f:
                f.write(text)
            (out_spec,) = jax.eval_shape(fn, *arg_specs)
            entry = dict(spec)
            entry.update(
                name=name,
                dtype=dname,
                file=fname,
                sha256=hashlib.sha256(text.encode()).hexdigest()[:16],
                inputs=[list(s.shape) for s in arg_specs],
                output=list(out_spec.shape),
            )
            manifest["variants"].append(entry)
            print(f"lowered {name}: {len(text)} chars")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(manifest['variants'])} variants to {args.out_dir}/manifest.json")


if __name__ == "__main__":
    main()
