#!/usr/bin/env python3
"""CI perf-regression gate over BENCH_hotpath.json.

Diffs a freshly-emitted bench JSON (the candidate) against the
checked-in repo-root seed (the baseline) and fails the build when:

  1. a kernel present in the baseline is missing from the candidate
     (schema regression — replaces the old ad-hoc `grep -q` lines);
  2. any candidate entry carries a nonzero ``allocs_per_run`` (the
     recycled-everything steady-state invariant);
  3. a (kernel, shape) pair present in both files with *real* timings on
     both sides regressed beyond ``--tolerance`` (median ratio).  Rows
     whose baseline or candidate median is the 0.0 placeholder are
     skipped, so the gate is meaningful from the first real baseline
     onward without blocking on the offline-seeded schema file; shape
     mismatches (e.g. tiny-smoke runs vs full-shape baselines) are
     skipped for the same reason.

With ``--compact OUT`` it also writes a trajectory-friendly compact JSON
(one line per kernel) and echoes it to stdout, so cross-PR perf tracking
reads straight out of the CI log instead of downloading artifacts.

Usage:
  tools/bench_gate.py --baseline BENCH_hotpath.json \
      --candidate rust/BENCH_hotpath.json [--tolerance 3.0] \
      [--compact rust/BENCH_compact.jsonl]
"""

from __future__ import annotations

import argparse
import json
import sys


# Every row the gate consumes must carry these; checks 1 and 3 index
# them directly, so a malformed row used to die as a raw KeyError
# traceback with no hint of which row was broken.
REQUIRED_FIELDS = ("kernel", "median_seconds")


def load(path: str) -> dict:
    """Read a bench JSON and validate row schema.

    A malformed file (missing ``results``, a non-object row, or a row
    missing a required field) exits non-zero with a diagnostic naming the
    file, the row index, the kernel (when present), and the missing
    field — not a bare ``KeyError`` traceback.
    """
    with open(path, encoding="utf-8") as fh:
        doc = json.load(fh)
    if not isinstance(doc.get("results"), list):
        raise SystemExit(f"{path}: no 'results' array")
    problems: list[str] = []
    for i, row in enumerate(doc["results"]):
        if not isinstance(row, dict):
            problems.append(f"results[{i}]: not an object ({type(row).__name__})")
            continue
        kernel = row.get("kernel", "<no kernel field>")
        for field in REQUIRED_FIELDS:
            if field not in row:
                problems.append(
                    f"results[{i}] (kernel '{kernel}'): missing required "
                    f"field '{field}'"
                )
    if problems:
        print(f"{path}: malformed bench JSON ({len(problems)} problem(s)):",
              file=sys.stderr)
        for p in problems:
            print(f"  - {p}", file=sys.stderr)
        raise SystemExit(1)
    return doc


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", required=True, help="checked-in seed/baseline JSON")
    ap.add_argument("--candidate", required=True, help="freshly emitted bench JSON")
    ap.add_argument(
        "--tolerance",
        type=float,
        default=3.0,
        help="max candidate/baseline median ratio before failing (default 3.0; "
        "CI runners are noisy, so this catches order-of-magnitude cliffs, "
        "not jitter)",
    )
    ap.add_argument("--compact", help="also write a one-line-per-kernel .jsonl here")
    args = ap.parse_args()

    baseline = load(args.baseline)
    candidate = load(args.candidate)
    failures: list[str] = []

    # 1. Every baseline kernel must still be emitted.
    want = {r["kernel"] for r in baseline["results"]}
    have = {r["kernel"] for r in candidate["results"]}
    for missing in sorted(want - have):
        failures.append(f"kernel '{missing}' missing from {args.candidate}")

    # 2. The steady state must stay allocation-free — and the field must
    #    keep being emitted: a kernel whose baseline row carries
    #    allocs_per_run must carry it in the candidate too, or the gate
    #    would pass vacuously after a bench refactor drops the counter.
    for r in candidate["results"]:
        if r.get("allocs_per_run", 0) != 0:
            failures.append(
                f"{r['kernel']} ({r.get('shape', '?')}): allocs_per_run = "
                f"{r['allocs_per_run']} (must be 0)"
            )
    counted = {r["kernel"] for r in baseline["results"] if "allocs_per_run" in r}
    for kernel in sorted(counted):
        rows = [r for r in candidate["results"] if r["kernel"] == kernel]
        if rows and not any("allocs_per_run" in r for r in rows):
            failures.append(
                f"{kernel}: baseline tracks allocs_per_run but the candidate "
                f"stopped emitting it (invariant no longer enforced)"
            )

    # 3. Median-ratio regression check on matching (kernel, shape) rows
    #    with real timings on both sides.
    base_by_key = {
        (r["kernel"], r.get("shape")): r["median_seconds"] for r in baseline["results"]
    }
    checked = 0
    for r in candidate["results"]:
        base = base_by_key.get((r["kernel"], r.get("shape")))
        cand = r["median_seconds"]
        if not base:  # baseline placeholder (0.0) or unmatched shape
            continue
        if not cand:
            # The baseline has a real timing but the candidate emitted
            # 0.0: only a broken timer or an accidental placeholder
            # produces that — fail loudly instead of skipping the kernel
            # out of the gate forever.
            failures.append(
                f"{r['kernel']} ({r.get('shape', '?')}): candidate median is 0.0 "
                f"but baseline has a real timing ({base:.6g}s) — timer broken?"
            )
            continue
        checked += 1
        ratio = cand / base
        if ratio > args.tolerance:
            failures.append(
                f"{r['kernel']} ({r.get('shape', '?')}): median {cand:.6g}s vs "
                f"baseline {base:.6g}s ({ratio:.2f}x > {args.tolerance:.2f}x)"
            )
    print(
        f"bench gate: {len(have)} kernels emitted, {len(want)} required, "
        f"{checked} median ratios checked (tolerance {args.tolerance:.2f}x)"
    )

    if args.compact:
        lines = [json.dumps(r, sort_keys=True) for r in candidate["results"]]
        with open(args.compact, "w", encoding="utf-8") as fh:
            fh.write("\n".join(lines) + "\n")
        print(f"--- compact trajectory ({args.compact}) ---")
        for line in lines:
            print(line)

    if failures:
        print(f"\nbench gate FAILED ({len(failures)} problem(s)):", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        return 1
    print("bench gate OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
