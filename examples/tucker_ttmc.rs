//! Tucker compression via distributed TTMc (Table IV's TTMc-05-M0
//! workload in its natural habitat).
//!
//! HOSVD-style pipeline on an order-5 tensor: project onto fixed
//! orthonormal factor bases with a distributed mode-0 TTM chain
//! (`ijklm,jb,kc,ld,me->ibcde`), then reconstruct and report the
//! compression error.  Factors are orthonormalized with Gram-Schmidt on
//! the leader; all heavy lifting is the distributed TTMc.
//!
//! ```bash
//! cargo run --release --example tucker_ttmc
//! ```

use deinsum::tensor::{contract, Tensor};
use deinsum::Session;

const N: usize = 16; // each of the 5 tensor modes
const R: usize = 6; // Tucker rank per compressed mode
const P: usize = 8;

/// Orthonormalize the columns of an (n, r) matrix (modified Gram-Schmidt).
fn orthonormalize(m: &Tensor) -> Tensor {
    let (n, r) = (m.dims()[0], m.dims()[1]);
    let mut cols: Vec<Vec<f64>> = (0..r)
        .map(|c| (0..n).map(|i| m.data()[i * r + c] as f64).collect())
        .collect();
    for c in 0..r {
        for prev in 0..c {
            let dot: f64 = cols[c].iter().zip(&cols[prev]).map(|(a, b)| a * b).sum();
            let (head, tail) = cols.split_at_mut(c);
            for (x, y) in tail[0].iter_mut().zip(&head[prev]) {
                *x -= dot * y;
            }
        }
        let norm: f64 = cols[c].iter().map(|x| x * x).sum::<f64>().sqrt().max(1e-12);
        for x in &mut cols[c] {
            *x /= norm;
        }
    }
    let mut data = vec![0.0f32; n * r];
    for (c, col) in cols.iter().enumerate() {
        for i in 0..n {
            data[i * r + c] = col[i] as f32;
        }
    }
    Tensor::from_vec(&[n, r], data).unwrap()
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    println!("Tucker compression of a {N}^5 tensor to core (16,{R},{R},{R},{R}), P = {P}\n");

    // A tensor with planted multilinear structure + noise so Tucker
    // compression is meaningful.
    let gtrue = Tensor::random(&[N, R, R, R, R], 50);
    let f_true: Vec<Tensor> =
        (1..5).map(|m| orthonormalize(&Tensor::random(&[N, R], 60 + m as u64))).collect();
    // X = G x1 U1 x2 U2 x3 U3 x4 U4 (mode 0 left uncompressed).
    let mut x = gtrue.clone();
    for (q, f) in f_true.iter().enumerate() {
        // expand R -> N in mode q+1: TTM with U (N,R) transposed use: ttm
        // wants (I_mode, R); here expanding, so factor is (R, N)?? use
        // einsum2 for clarity.
        let modes: Vec<char> = "ijklm".chars().collect();
        let mut xi: Vec<char> = modes[..x.order()].to_vec();
        xi[q + 1] = 'z';
        let mut oi = xi.clone();
        oi[q + 1] = modes[q + 1];
        x = contract::einsum2(&x, &xi, f, &[modes[q + 1], 'z'], &oi).unwrap();
    }
    let noise = Tensor::random(x.dims(), 70);
    for (xd, nd) in x.data_mut().iter_mut().zip(noise.data()) {
        *xd += 5e-3 * nd;
    }
    let x_norm = x.norm();

    // --- distributed TTMc: core = X x1 U1^T ... (einsum ijklm,jb,kc,ld,me->ibcde)
    let expr = "ijklm,jb,kc,ld,me->ibcde";
    let shapes = vec![
        vec![N, N, N, N, N],
        vec![N, R],
        vec![N, R],
        vec![N, R],
        vec![N, R],
    ];
    let session = Session::builder().ranks(P).build()?;
    let mut program = session.compile(expr, &shapes)?;
    let mut baseline = session.compile_baseline(expr, &shapes)?;
    println!("schedule:\n{}", program.schedule());

    let inputs: Vec<Tensor> = std::iter::once(x.clone())
        .chain(f_true.iter().cloned())
        .collect();
    let rep = program.run(&inputs)?;
    let brep = baseline.run(&inputs)?;
    assert!(rep.output.rel_error(&brep.output) < 1e-3);
    println!(
        "TTMc core computed: {:?}; deinsum {:.5}s vs ctf-like {:.5}s ({:.2}x)",
        rep.output.dims(),
        rep.time.total(),
        brep.time.total(),
        brep.time.total() / rep.time.total().max(1e-12)
    );

    // --- reconstruct and measure compression error -------------------------
    let mut rec = rep.output.clone(); // (N, R, R, R, R)
    for (q, f) in f_true.iter().enumerate() {
        let modes: Vec<char> = "ijklm".chars().collect();
        let mut xi: Vec<char> = modes[..rec.order()].to_vec();
        xi[q + 1] = 'z';
        let mut oi = xi.clone();
        oi[q + 1] = modes[q + 1];
        rec = contract::einsum2(&rec, &xi, f, &[modes[q + 1], 'z'], &oi).unwrap();
    }
    let mut diff = rec;
    for (d, &xv) in diff.data_mut().iter_mut().zip(x.data()) {
        *d -= xv;
    }
    let rel = diff.norm() / x_norm;
    let ratio = (N * R * R * R * R + 4 * N * R) as f64 / (N * N * N * N * N) as f64;
    println!(
        "\ncompression: {:.1}% of original storage, reconstruction error {:.4}",
        100.0 * ratio,
        rel
    );
    assert!(rel < 0.05, "Tucker reconstruction error too large: {rel}");
    println!("tucker_ttmc OK");
    Ok(())
}
