//! End-to-end driver: CP decomposition by Alternating Least Squares on a
//! synthetic low-rank tensor — the application the paper's MTTKRP
//! benchmarks stand in for (§I: "the main computational kernel of the CP
//! decomposition").
//!
//! Every ALS sweep runs three *distributed* MTTKRPs (modes 0, 1, 2) on
//! P simulated ranks; the R×R normal equations are solved on the
//! leader.  The fit curve (1 − ‖X − ⟦A,B,C⟧‖/‖X‖) is logged per sweep
//! and must recover the planted rank — this is the system prompt's
//! end-to-end validation run, recorded in EXPERIMENTS.md.
//!
//! This is the workload the `Session`/`Program` handles were shaped
//! for: each mode's MTTKRP is **compiled once** and re-run every sweep,
//! so each `Program`'s persistent machine recycles its staging,
//! redistribution and output buffers across all sweeps (the old
//! single-coordinator wiring thrashed its store when alternating six
//! plans through one machine).
//!
//! ```bash
//! cargo run --release --example cp_als [-- --artifacts artifacts]
//! ```

use deinsum::tensor::{contract, Tensor};
use deinsum::{Program, Session};

const N: usize = 64;
const RANK: usize = 8;
const P: usize = 8;
const SWEEPS: usize = 25;

/// Solve `X * G = M` for X, i.e. X = M * G^{-1}, G symmetric R×R
/// (Gaussian elimination with partial pivoting; R is tiny).
fn solve_right(m: &Tensor, g: &Tensor) -> Tensor {
    let r = g.dims()[0];
    // Build augmented [G^T | I] and invert (G symmetric -> G^T = G).
    let mut a: Vec<f64> = g.data().iter().map(|&x| x as f64).collect();
    let mut inv = vec![0.0f64; r * r];
    for i in 0..r {
        inv[i * r + i] = 1.0;
    }
    for col in 0..r {
        // pivot
        let mut piv = col;
        for row in col + 1..r {
            if a[row * r + col].abs() > a[piv * r + col].abs() {
                piv = row;
            }
        }
        for c in 0..r {
            a.swap(col * r + c, piv * r + c);
            inv.swap(col * r + c, piv * r + c);
        }
        let d = a[col * r + col];
        assert!(d.abs() > 1e-12, "singular Gram matrix");
        for c in 0..r {
            a[col * r + c] /= d;
            inv[col * r + c] /= d;
        }
        for row in 0..r {
            if row == col {
                continue;
            }
            let f = a[row * r + col];
            if f == 0.0 {
                continue;
            }
            for c in 0..r {
                a[row * r + c] -= f * a[col * r + c];
                inv[row * r + c] -= f * inv[col * r + c];
            }
        }
    }
    // X = M @ G^{-1}
    let ginv =
        Tensor::from_vec(&[r, r], inv.iter().map(|&x| x as f32).collect()).unwrap();
    contract::gemm(m, &ginv).unwrap()
}

/// Gram matrix AᵀA (R×R).
fn gram(a: &Tensor) -> Tensor {
    let at = a.permute(&[1, 0]);
    contract::gemm(&at, a).unwrap()
}

/// Hadamard product of R×R matrices.
fn hadamard(a: &Tensor, b: &Tensor) -> Tensor {
    let mut out = a.clone();
    for (o, &x) in out.data_mut().iter_mut().zip(b.data()) {
        *o *= x;
    }
    out
}

/// Reconstruct ⟦A,B,C⟧ (small sizes only; fit evaluation).
fn reconstruct(a: &Tensor, b: &Tensor, c: &Tensor) -> Tensor {
    // ijk = sum_r A[i,r] B[j,r] C[k,r]: krp(B,C) then GEMM.
    let k = contract::krp_chain(&[b, c]).unwrap(); // (J, K, R)
    let r = k.dims()[2];
    let km = k.reshape(&[b.dims()[0] * c.dims()[0], r]).unwrap();
    let m = contract::gemm(a, &km.permute(&[1, 0])).unwrap(); // (I, J*K)
    m.reshape(&[a.dims()[0], b.dims()[0], c.dims()[0]]).unwrap()
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let use_pjrt = std::env::args().any(|x| x == "--artifacts");
    println!("CP-ALS on a synthetic rank-{RANK} {N}x{N}x{N} tensor, P = {P} ranks\n");

    // Planted low-rank tensor + mild noise.
    let a_true = Tensor::random(&[N, RANK], 1);
    let b_true = Tensor::random(&[N, RANK], 2);
    let c_true = Tensor::random(&[N, RANK], 3);
    let mut x = reconstruct(&a_true, &b_true, &c_true);
    let noise = Tensor::random(&[N, N, N], 4);
    for (xd, nd) in x.data_mut().iter_mut().zip(noise.data()) {
        *xd += 1e-3 * nd;
    }
    let x_norm = x.norm();

    // Compile once: one distributed-MTTKRP program per mode (plus the
    // CTF-like baseline comparator), re-run every sweep.
    let mut builder = Session::builder().ranks(P);
    if use_pjrt {
        builder = builder.artifacts("artifacts");
    }
    let session = builder.build_or_native();
    let exprs = ["ijk,ja,ka->ia", "ijk,ia,ka->ja", "ijk,ia,ja->ka"];
    let shapes = vec![vec![N, N, N], vec![N, RANK], vec![N, RANK]];
    let mut programs: Vec<Program> = exprs
        .iter()
        .map(|e| session.compile(e, &shapes))
        .collect::<deinsum::Result<_>>()?;
    let mut base_programs: Vec<Program> = exprs
        .iter()
        .map(|e| session.compile_baseline(e, &shapes))
        .collect::<deinsum::Result<_>>()?;

    // Random init.
    let mut fac = [
        Tensor::random(&[N, RANK], 10),
        Tensor::random(&[N, RANK], 11),
        Tensor::random(&[N, RANK], 12),
    ];

    let mut total = deinsum::sim::TimeBreakdown::default();
    let mut base_total = deinsum::sim::TimeBreakdown::default();
    println!("{:>5} {:>12} {:>14} {:>14}", "sweep", "fit", "deinsum s", "ctf-like s");
    for sweep in 0..SWEEPS {
        for mode in 0..3 {
            let others: Vec<usize> = (0..3).filter(|&m| m != mode).collect();
            let inputs =
                vec![x.clone(), fac[others[0]].clone(), fac[others[1]].clone()];
            // Deinsum distributed MTTKRP.
            let rep = programs[mode].run(&inputs)?;
            total.compute += rep.time.compute;
            total.comm += rep.time.comm;
            // Baseline for the time comparison (same math, two-step).
            let brep = base_programs[mode].run(&inputs)?;
            base_total.compute += brep.time.compute;
            base_total.comm += brep.time.comm;
            assert!(rep.output.rel_error(&brep.output) < 1e-3);
            // Normal equations on the leader: F_mode = M (G1 ∘ G2)^{-1}.
            let g = hadamard(&gram(&fac[others[0]]), &gram(&fac[others[1]]));
            fac[mode] = solve_right(&rep.output, &g);
        }
        let rec = reconstruct(&fac[0], &fac[1], &fac[2]);
        let mut diff = rec.clone();
        for (d, &xv) in diff.data_mut().iter_mut().zip(x.data()) {
            *d -= xv;
        }
        let fit = 1.0 - diff.norm() / x_norm;
        println!(
            "{:>5} {:>12.6} {:>14.5} {:>14.5}",
            sweep,
            fit,
            total.total(),
            base_total.total()
        );
        if fit > 0.9999 {
            break;
        }
    }

    let rec = reconstruct(&fac[0], &fac[1], &fac[2]);
    let mut diff = rec;
    for (d, &xv) in diff.data_mut().iter_mut().zip(x.data()) {
        *d -= xv;
    }
    let fit = 1.0 - diff.norm() / x_norm;
    println!(
        "\nfinal fit {fit:.6} (planted rank recovered: {})",
        if fit > 0.99 { "YES" } else { "NO" }
    );
    println!(
        "cumulative time: deinsum {:.5}s vs ctf-like {:.5}s ({:.2}x)",
        total.total(),
        base_total.total(),
        base_total.total() / total.total().max(1e-12)
    );
    // Per-program counters only (engine scratch is session-wide).
    let st = programs[0].stats();
    println!(
        "mode-0 program: {} runs, {} whole-tensor recycles ({} tensor allocations)",
        st.runs,
        st.reuses(),
        st.tensor_allocs()
    );
    assert!(fit > 0.99, "CP-ALS failed to recover the planted factors");
    println!("cp_als OK");
    Ok(())
}
