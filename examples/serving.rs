//! Closed-loop multi-tenant serving demo: a `Server` over one shared
//! `Session`, driven by concurrent clients with mixed MTTKRP/TTMc/GEMM
//! traffic.
//!
//! Three tenants each run a closed loop (submit → wait → resubmit, with
//! the reply's output tensor recycled as the next request's
//! destination — the zero-allocation `run_into` path end to end) over a
//! pool of distinct program keys.  Requests are routed by `(expr,
//! shapes)` key so identical programs coalesce onto one warm worker
//! state; the demo prints per-tenant queue depth, p50/p99 latency,
//! throughput, warm-program hit rate, and the steady-state tensor
//! allocation count (which must stop growing once every program is
//! warm), then cross-checks one served output against a direct serial
//! run.
//!
//! With `DEINSUM_FAULT_SEED` set (the CI chaos leg), the server inherits
//! the env-seeded fault plan — strided transient run failures, worker
//! panics, injected latency — and the same closed loop must still
//! complete with **zero lost tickets**: every wait returns (success or a
//! typed retryable error), failed requests are resubmitted with a fresh
//! destination, and the restart/retry counters are printed alongside the
//! usual steady-state accounting.
//!
//! ```bash
//! cargo run --release --example serving            # full shapes
//! cargo run --release --example serving -- --tiny  # CI smoke
//! DEINSUM_FAULT_SEED=7 cargo run --release --example serving -- --tiny  # chaos smoke
//! ```

use std::sync::Arc;

use deinsum::{ServeRequest, Server, Session, Tensor};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let tiny = std::env::args().any(|a| a == "--tiny");
    let chaos = std::env::var("DEINSUM_FAULT_SEED").is_ok();
    let n = if tiny { 10 } else { 32 };
    let r = if tiny { 3 } else { 8 };
    let rounds = if tiny { 6 } else { 12 };
    let workers = 8usize;

    // The traffic mix: CP-ALS-style MTTKRPs (all three modes), a
    // Tucker-style TTMc, and GEMM fills — six distinct program keys.
    let keys: Vec<(String, Vec<Vec<usize>>)> = vec![
        ("ijk,ja,ka->ia".into(), vec![vec![n, n, n], vec![n, r], vec![n, r]]),
        ("ijk,ia,ka->ja".into(), vec![vec![n, n, n], vec![n, r], vec![n, r]]),
        ("ijk,ia,ja->ka".into(), vec![vec![n, n, n], vec![n, r], vec![n, r]]),
        (
            "ijkl,jb,kc,ld->ibcd".into(),
            vec![vec![n, n, n, n], vec![n, r], vec![n, r], vec![n, r]],
        ),
        ("ij,jk->ik".into(), vec![vec![2 * n, n], vec![n, 2 * n]]),
        ("ij,jk,kl->il".into(), vec![vec![n, n], vec![n, n], vec![n, n]]),
    ];
    let inputs: Vec<Arc<Vec<Tensor>>> = keys
        .iter()
        .enumerate()
        .map(|(i, (_, shapes))| {
            Arc::new(
                shapes
                    .iter()
                    .enumerate()
                    .map(|(j, s)| Tensor::random(s, (100 * i + j) as u64))
                    .collect::<Vec<_>>(),
            )
        })
        .collect();

    println!(
        "serving {} program keys (n = {n}, r = {r}) on {workers} workers, \
         3 tenants x {rounds} closed-loop rounds{}\n",
        keys.len(),
        if chaos { " [fault injection armed via DEINSUM_FAULT_SEED]" } else { "" }
    );
    let session = Session::builder().ranks(8).build_or_native();
    let server = Arc::new(Server::builder(session).workers(workers).build());

    // Each tenant drives every key per round, recycling its reply
    // outputs as next-round destinations.
    std::thread::scope(|s| {
        for tenant_id in 0..3usize {
            let server = Arc::clone(&server);
            let keys = &keys;
            let inputs = &inputs;
            s.spawn(move || {
                let tenant = format!("tenant-{tenant_id}");
                let mut dests: Vec<Option<Tensor>> = keys
                    .iter()
                    .map(|(expr, shapes)| {
                        Some(Tensor::zeros(
                            &Server::output_dims(expr, shapes).expect("valid key"),
                        ))
                    })
                    .collect();
                for _ in 0..rounds {
                    let tickets: Vec<_> = keys
                        .iter()
                        .zip(inputs)
                        .enumerate()
                        .map(|(q, ((expr, shapes), ins))| {
                            server
                                .submit(ServeRequest {
                                    tenant: tenant.clone(),
                                    expr: expr.clone(),
                                    shapes: shapes.clone(),
                                    inputs: Arc::clone(ins),
                                    dest: dests[q].take().expect("dest recycled"),
                                })
                                .expect("submit")
                        })
                        .collect();
                    for (q, t) in tickets.into_iter().enumerate() {
                        dests[q] = match t.wait() {
                            Ok(reply) => Some(reply.output),
                            // Under the chaos leg a request may exhaust
                            // its retry budget with a typed retryable
                            // error; its destination died with it, so
                            // mint a fresh one and keep the loop closed.
                            Err(e) if chaos && e.is_retryable() => {
                                let (expr, shapes) = &keys[q];
                                Some(Tensor::zeros(
                                    &Server::output_dims(expr, shapes)
                                        .expect("valid key"),
                                ))
                            }
                            Err(e) => panic!("serve failed outside injected faults: {e}"),
                        };
                    }
                }
            });
        }
    });

    // --- per-tenant accounting ----------------------------------------------
    println!(
        "{:<10} {:>6} {:>6} {:>10} {:>10} {:>10} {:>9} {:>7}",
        "tenant", "done", "errs", "p50", "p99", "req/s", "hit rate", "allocs"
    );
    for tenant in server.tenants() {
        let st = server.tenant_stats(&tenant).expect("tenant seen");
        println!(
            "{:<10} {:>6} {:>6} {:>9.2}ms {:>9.2}ms {:>10.1} {:>9.2} {:>7}",
            tenant,
            st.completed,
            st.errors,
            st.p50_latency_s * 1e3,
            st.p99_latency_s * 1e3,
            st.throughput_rps,
            st.hit_rate(),
            st.tensor_allocs
        );
    }
    let total = server.stats();
    println!(
        "\ntotal: {} served ({} coalesced behind a same-key leader), queue depth {}, \
         {} tensor allocations / {} recycles",
        total.completed, total.coalesced, total.queue_depth, total.tensor_allocs,
        total.tensor_reuses
    );
    println!(
        "robustness: {} worker restarts, {} retries, {} shed, {} timeouts, {} errors",
        total.restarts, total.retries, total.shed, total.timeouts, total.errors
    );
    let expected = 3 * rounds as u64 * keys.len() as u64;
    // The closed-loop invariant holds with or without injected faults:
    // every accepted ticket resolved — none lost, none hung.
    assert_eq!(
        total.completed + total.errors,
        expected,
        "zero lost tickets ({total:?})"
    );
    assert_eq!(total.in_flight, 0);
    if !chaos {
        assert_eq!(total.errors, 0, "no request may fail without injected faults");
        assert_eq!(total.completed, expected);
        assert_eq!(total.restarts, 0, "no injected faults, no supervisor restarts");
    }
    // Every program is warm after round one; the remaining traffic must
    // recycle instead of allocating.
    assert!(
        total.tensor_reuses > total.tensor_allocs,
        "steady-state serving should be dominated by recycling ({total:?})"
    );

    // --- cross-check one key against a direct serial run ---------------------
    let (expr, shapes) = &keys[0];
    let direct = Session::builder()
        .ranks(8)
        .build_or_native()
        .compile(expr, shapes)?
        .run(&inputs[0])?
        .output;
    // Under chaos the verify request itself may be failed by the plan;
    // resubmit until it lands (bounded — the typed error classes are
    // retryable by contract).
    let reply = loop {
        let attempt = server
            .submit(ServeRequest {
                tenant: "verify".into(),
                expr: expr.clone(),
                shapes: shapes.clone(),
                inputs: Arc::clone(&inputs[0]),
                dest: Tensor::zeros(&Server::output_dims(expr, shapes)?),
            })?
            .wait();
        match attempt {
            Ok(reply) => break reply,
            Err(e) if chaos && e.is_retryable() => continue,
            Err(e) => return Err(e.into()),
        }
    };
    assert!(
        reply.output.allclose(&direct, 0.0, 0.0),
        "served output must be bitwise identical to a direct run"
    );
    println!("served output bitwise-identical to direct run; serving OK");
    Ok(())
}
