//! Quickstart: the paper's §II worked example, end to end.
//!
//! Plans and runs `ijk,ja,ka,al->il` on 8 simulated ranks, printing the
//! generated schedule (the §II-E "intermediate program"), the I/O lower
//! bounds behind it (§IV-E), and the run's time/communication breakdown.
//!
//! ```bash
//! cargo run --release --example quickstart [-- --artifacts artifacts]
//! ```

use deinsum::coordinator::Coordinator;
use deinsum::einsum::EinsumSpec;
use deinsum::planner::{plan, PlannerConfig};
use deinsum::runtime::KernelEngine;
use deinsum::sim::NetworkModel;
use deinsum::soap::{self, Statement};
use deinsum::tensor::Tensor;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let use_pjrt = std::env::args().any(|a| a == "--artifacts");

    // --- the paper's worked example ---------------------------------------
    let n = 256usize;
    let r = 24usize;
    let expr = "ijk,ja,ka,al->il";
    let shapes = vec![vec![n, n, n], vec![n, r], vec![n, r], vec![r, n]];
    let spec = EinsumSpec::parse(expr, &shapes)?;
    println!("einsum: {expr}   (N = {n}, R = {r})");
    println!(
        "naive FLOPs: {:.3e}; iteration space {:.3e}\n",
        spec.naive_flops() as f64,
        spec.iteration_space() as f64
    );

    // --- §IV-E: the theory the schedule is built on ------------------------
    let s = 1e6;
    let mt = Statement::mttkrp3(1e12, 1e12, 1e12, 1e12).io_bound(s);
    println!("SOAP analysis at S = {s:.0e} elements:");
    println!(
        "  fused MTTKRP rho = {:.3e}  (paper closed form S^(2/3)/3 = {:.3e})",
        mt.rho,
        soap::mttkrp_rho_closed_form(s)
    );
    println!(
        "  improvement over previously best-known bound: {:.2}x (paper: 6.24x)\n",
        soap::mttkrp_improvement_factor()
    );

    // --- plan on 8 ranks ----------------------------------------------------
    let p = 8;
    let pl = plan(&spec, p, &PlannerConfig::default())?;
    println!("generated schedule (paper §II-E):\n{}", pl.render());

    // --- execute on the simulated machine -----------------------------------
    let inputs: Vec<Tensor> = shapes
        .iter()
        .enumerate()
        .map(|(i, s)| Tensor::random(s, 7 + i as u64))
        .collect();
    let engine = if use_pjrt {
        KernelEngine::pjrt("artifacts").unwrap_or_else(|e| {
            eprintln!("note: PJRT unavailable ({e}); native kernels");
            KernelEngine::native()
        })
    } else {
        KernelEngine::native()
    };
    let coord = Coordinator::new(&engine, NetworkModel::aries());
    let rep = coord.run(&pl, &inputs)?;

    println!("run on P = {p} simulated ranks:");
    for t in &rep.per_term {
        println!(
            "  {:<8} compute {:>9.5}s   comm {:>9.5}s",
            t.name, t.compute, t.comm
        );
    }
    println!(
        "  total    compute {:>9.5}s   comm {:>9.5}s   =  {:.5}s",
        rep.time.compute,
        rep.time.comm,
        rep.time.total()
    );
    println!(
        "  comm volumes: {} p2p bytes in {} msgs, {} allreduce bytes",
        rep.comm.p2p_bytes, rep.comm.p2p_msgs, rep.comm.allreduce_bytes
    );

    // --- verify against a single-rank run ------------------------------------
    let pl1 = plan(&spec, 1, &PlannerConfig::default())?;
    let rep1 = coord.run(&pl1, &inputs)?;
    let rel = rep.output.rel_error(&rep1.output);
    println!("\nP={p} vs P=1 relative error: {rel:.3e}");
    assert!(rel < 1e-4, "distributed result diverged");
    println!("quickstart OK");
    Ok(())
}
