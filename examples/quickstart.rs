//! Quickstart: the paper's §II worked example, end to end, through the
//! `Session`/`Program` front door.
//!
//! Compiles `ijk,ja,ka,al->il` once into an I/O-optimal distributed
//! program on 8 simulated ranks, prints the generated schedule (the
//! §II-E "intermediate program") and the I/O lower bounds behind it
//! (§IV-E), runs it, and verifies against a single-rank run — no
//! hand-wiring of the planner or coordinator anywhere.
//!
//! ```bash
//! cargo run --release --example quickstart [-- --artifacts artifacts]
//! ```

use deinsum::soap::{self, Statement};
use deinsum::{Session, Tensor};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let use_pjrt = std::env::args().any(|a| a == "--artifacts");

    // --- the whole §II worked example, front-door only ----------------------
    let n = 256usize;
    let r = 24usize;
    let expr = "ijk,ja,ka,al->il";
    let shapes = vec![vec![n, n, n], vec![n, r], vec![n, r], vec![r, n]];
    let mut builder = Session::builder().ranks(8);
    if use_pjrt {
        builder = builder.artifacts("artifacts");
    }
    let session = builder.build_or_native();
    let mut program = session.compile(expr, &shapes)?;
    let inputs: Vec<Tensor> = shapes
        .iter()
        .enumerate()
        .map(|(i, s)| Tensor::random(s, 7 + i as u64))
        .collect();
    let rep = program.run(&inputs)?;

    println!("einsum: {expr}   (N = {n}, R = {r})");
    println!(
        "naive FLOPs: {:.3e}; iteration space {:.3e}\n",
        program.spec().naive_flops() as f64,
        program.spec().iteration_space() as f64
    );
    println!("generated schedule (paper §II-E):\n{}", program.schedule());

    // --- §IV-E: the theory the schedule is built on ------------------------
    let s = 1e6;
    let mt = Statement::mttkrp3(1e12, 1e12, 1e12, 1e12).io_bound(s);
    println!("SOAP analysis at S = {s:.0e} elements:");
    println!(
        "  fused MTTKRP rho = {:.3e}  (paper closed form S^(2/3)/3 = {:.3e})",
        mt.rho,
        soap::mttkrp_rho_closed_form(s)
    );
    println!(
        "  improvement over previously best-known bound: {:.2}x (paper: 6.24x)\n",
        soap::mttkrp_improvement_factor()
    );

    // --- the run's accounting ----------------------------------------------
    println!("run on P = {} simulated ranks:", program.ranks());
    for t in &rep.per_term {
        println!(
            "  {:<8} compute {:>9.5}s   comm {:>9.5}s",
            t.name, t.compute, t.comm
        );
    }
    println!(
        "  total    compute {:>9.5}s   comm {:>9.5}s   =  {:.5}s",
        rep.time.compute,
        rep.time.comm,
        rep.time.total()
    );
    println!(
        "  comm volumes: {} p2p bytes in {} msgs, {} allreduce bytes",
        rep.comm.p2p_bytes, rep.comm.p2p_msgs, rep.comm.allreduce_bytes
    );

    // --- compile-once pays off: a rerun recycles every buffer ---------------
    let warm = program.stats();
    let mut out = Tensor::zeros(&program.output_dims());
    program.run_into(&inputs, &mut out)?;
    let after = program.stats();
    println!(
        "\nrerun into a recycled output: {} new allocations ({} buffers recycled)",
        after.allocs() - warm.allocs(),
        after.reuses() - warm.reuses()
    );
    assert!(out.allclose(&rep.output, 0.0, 0.0), "rerun must be bitwise stable");

    // --- verify against a single-rank program --------------------------------
    let mut p1 = session.compile_on(expr, &shapes, 1)?;
    let rep1 = p1.run(&inputs)?;
    let rel = rep.output.rel_error(&rep1.output);
    println!("P=8 vs P=1 relative error: {rel:.3e}");
    assert!(rel < 1e-4, "distributed result diverged");
    println!("quickstart OK");
    Ok(())
}
