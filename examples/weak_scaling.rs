//! Fig. 5 harness: weak-scaling series for every Table IV benchmark,
//! Deinsum (compute + comm split) vs the CTF-like baseline.
//!
//! Prints one sub-table per benchmark with P = 1..=max_nodes (powers of
//! two), i.e. the same series the paper plots, plus the §VI-B headline
//! numbers (per-benchmark speedup at the largest P and the geometric
//! mean over all points).
//!
//! ```bash
//! cargo run --release --example weak_scaling -- [--nodes 64] [--size-factor 16] [--filter MTTKRP]
//! ```

use deinsum::bench_support::{self, geomean, header, row};
use deinsum::Session;

fn flag(args: &[String], name: &str) -> Option<String> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .cloned()
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let max_nodes: usize =
        flag(&args, "--nodes").and_then(|v| v.parse().ok()).unwrap_or(64);
    let sf: usize =
        flag(&args, "--size-factor").and_then(|v| v.parse().ok()).unwrap_or(16);
    let filter = flag(&args, "--filter").unwrap_or_default();

    let mut builder = Session::builder();
    if let Some(dir) = flag(&args, "--artifacts") {
        builder = builder.artifacts(dir);
    }
    // One session for the whole sweep: every (benchmark, P, scheduler)
    // plan lands in its cache.
    let session = builder.plan_cache_capacity(256).build_or_native();

    println!(
        "Fig. 5 reproduction (size-factor {sf}; paper sizes = 1): weak scaling to {max_nodes} simulated nodes\n"
    );
    let mut all_points = Vec::new();
    let mut final_speedups = Vec::new();
    for def in bench_support::suite(sf) {
        if !filter.is_empty() && !def.name.contains(&filter) {
            continue;
        }
        println!("== {} ({}) ==", def.name, def.expr);
        println!("{}", header());
        let mut p = 1usize;
        let mut last = None;
        while p <= max_nodes {
            let (pt, _, _) = bench_support::run_point(&def, p, &session)?;
            println!("{}", row(&pt));
            last = Some(pt.speedup);
            all_points.push(pt);
            p *= 2;
        }
        if let Some(s) = last {
            final_speedups.push((def.name.clone(), s));
        }
        println!();
    }

    println!("== headline (paper §VI-B analogue) ==");
    for (name, s) in &final_speedups {
        println!("  {name:<14} speedup at P={max_nodes}: {s:.2}x");
    }
    println!(
        "  geometric mean over all points: {:.2}x (paper: 4.18x on Piz Daint)",
        geomean(&all_points)
    );
    Ok(())
}
